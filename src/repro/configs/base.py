"""Architecture + shape configuration system.

Every assigned architecture is a :class:`ModelConfig` in its own module under
``repro.configs``; ``get_config(name)`` resolves them and
``reduced_config(cfg)`` derives the CPU-smoke-test variant (same family, tiny
dims).  Input shapes are the four assigned workload cells.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config",
           "reduced_config", "list_archs", "runnable_cells", "cell_skips"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0

    # attention flavor
    attention: str = "full"       # full | local_global | swa_global | none
    window_size: int = 4096
    global_layers: Tuple[int, ...] = ()   # explicit global-attn layer ids
    global_every: int = 0                 # gemma2-style alternation period
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0

    # SSM / recurrent
    ssm_state: int = 0
    ssm_expand: int = 2
    rwkv: bool = False

    # hybrid (parallel attn + ssm heads, Hymba)
    hybrid: bool = False

    # encoder-decoder
    is_encdec: bool = False
    n_encoder_layers: int = 0
    encoder_len_ratio: float = 1.0   # encoder source len = seq_len * ratio

    # multimodal frontend stub
    frontend: str = "none"           # none | vision_patches | audio_frames
    n_frontend_tokens: int = 0

    # misc
    act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    residual_scale: float = 1.0      # minicpm depth-scaled residuals
    embed_scale: float = 1.0
    logit_scale: float = 1.0
    sub_quadratic: bool = False      # eligible for long_500k
    notes: str = ""

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim always
        divides the TP axis (Megatron-style padding; padded logit positions
        are masked to -inf before the softmax)."""
        return ((self.vocab_size + 255) // 256) * 256

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        per_layer = 0
        if self.attention != "none":
            per_layer += d * self.q_dim + 2 * d * self.kv_dim \
                + self.q_dim * d
        if self.rwkv:
            per_layer += 4 * d * d + d * f + f * d   # time-mix + channel-mix
        elif self.n_experts > 0:
            per_layer += self.n_experts * 3 * d * f + d * self.n_experts
            per_layer += self.n_shared_experts * 3 * d * f
        else:
            per_layer += 3 * d * f
        if self.hybrid:
            inner = self.ssm_expand * d
            per_layer += 2 * d * inner + inner * d \
                + inner * (2 * self.ssm_state)
        total = self.n_layers * per_layer
        total += v * d * (1 if self.tie_embeddings else 2)
        if self.is_encdec:
            enc_layer = d * self.q_dim + 2 * d * self.kv_dim \
                + self.q_dim * d + 3 * d * f
            total += self.n_encoder_layers * enc_layer
            total += self.n_layers * (d * self.q_dim + 2 * d * self.kv_dim
                                      + self.q_dim * d)  # cross-attn
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE-aware), for 6·N_active·D."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = dataclasses.replace(self, n_experts=0,
                                         n_shared_experts=0)
        base = dense_like.param_count() - self.n_layers * 3 * d * f
        active = (self.experts_per_token + self.n_shared_experts) * 3 * d * f
        return base + self.n_layers * active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

_ARCH_MODULES = {
    "hymba-1.5b": "hymba_1p5b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "phi3-medium-14b": "phi3_medium_14b",
    "minicpm-2b": "minicpm_2b",
    "qwen2.5-14b": "qwen2p5_14b",
    "gemma2-2b": "gemma2_2b",
    "seamless-m4t-large-v2": "seamless_m4t_v2",
    "pixtral-12b": "pixtral_12b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list_archs()}")
    import importlib
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    n_kv = min(cfg.n_kv_heads, 2)
    n_heads = max(2, min(4, cfg.n_heads))
    # keep q/kv grouping valid
    if n_heads % n_kv != 0:
        n_kv = 1
    return dataclasses.replace(
        cfg,
        n_layers=2 if not cfg.is_encdec else 2,
        n_encoder_layers=2 if cfg.is_encdec else 0,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=16,
        d_ff=96 if cfg.n_experts == 0 else 32,
        vocab_size=251,
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        window_size=min(cfg.window_size, 8),
        global_layers=(0,) if cfg.global_layers else (),
        ssm_state=min(cfg.ssm_state, 4) if cfg.ssm_state else 0,
        n_frontend_tokens=8 if cfg.n_frontend_tokens else 0,
    )


def cell_skips() -> Dict[Tuple[str, str], str]:
    """(arch, shape) -> reason, for the 8 documented skips."""
    skips: Dict[Tuple[str, str], str] = {}
    for arch in list_archs():
        cfg = get_config(arch)
        if not cfg.sub_quadratic:
            skips[(arch, "long_500k")] = (
                "pure full-attention architecture: 512k-token single-step "
                "decode requires sub-quadratic sequence mixing "
                "(DESIGN.md §3)")
    return skips


def runnable_cells() -> List[Tuple[str, str]]:
    skips = cell_skips()
    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            if (arch, shape) not in skips:
                cells.append((arch, shape))
    return cells
