"""Hymba-1.5B — hybrid parallel attention + Mamba heads [arXiv:2411.13676; hf].

32L, d_model=1600, 25 query heads (GQA kv=5, head 64), d_ff=5504,
vocab=32001, ssm_state=16.  Per the paper: most layers use sliding-window
attention with three full-attention layers (first / middle / last); every
block runs attention heads and SSM heads *in parallel* on the same input and
fuses their (normalized, scaled) outputs.  Sub-quadratic => runs long_500k.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32001,
    attention="swa_global",
    window_size=1024,
    global_layers=(0, 15, 31),
    ssm_state=16,
    ssm_expand=2,
    hybrid=True,
    act="silu",
    sub_quadratic=True,
    notes="parallel attn+mamba heads; SWA + 3 global layers; meta tokens "
          "omitted (128 registers would add <0.1% FLOPs)",
)
