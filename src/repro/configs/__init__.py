"""Per-architecture configs + shape cells (assigned pool)."""

from .base import (SHAPES, ModelConfig, ShapeConfig, cell_skips, get_config,
                   list_archs, reduced_config, runnable_cells)

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "cell_skips",
           "get_config", "list_archs", "reduced_config", "runnable_cells"]
