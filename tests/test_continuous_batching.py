"""Continuous-batching admission loop + shape-bucketed executables.

Four layers of guarantees:

1. **Deterministic admission semantics** (fake clock, no threads, no
   sleeps): a group flushes exactly when its oldest request's latency
   budget expires or it reaches ``max_batch_requests``; the admit/flush
   event hooks observe every transition; backpressure rejects over-bound
   submits.
2. **Bucketed-padded execution is bit-exact** vs natural-shape execution
   for row counts covering 0, 1, bucket boundaries and boundaries±1.
3. **Bounded compiles**: varying batch sizes hit O(log max_batch) compiled
   executables — signature misses and shape-driven (bucket) compiles are
   split counters, and actual jit traces match the bucket count.
4. **Background loop** (real clock, timeout-guarded): ledger invariants
   hold under multi-thread load, ``close()`` drains in-flight requests
   without deadlock, and ``PredictionTicket.result(timeout=...)`` still
   raises ``TimeoutError`` while the loop is running.
"""

import threading

import numpy as np
import pytest

from repro.core import ModelStore, OptimizerConfig
from repro.core import codegen
from repro.data import hospital_tables
from repro.ml import DecisionTree, Pipeline, PipelineMetadata, StandardScaler
from repro.relational.table import Table
from repro.serve import (AdmissionConfig, AdmissionQueueFull, ManualClock,
                         PredictionService)

pytestmark = pytest.mark.tier1

N_ROWS = 400
FEATS = ["age", "gender", "pregnant", "rcount"]
SQL = "SELECT pid, PREDICT(MODEL='m') AS p FROM patient_info WHERE age > 30"
BUCKET = 8          # min_bucket_rows used throughout: boundaries at 8, 16...


@pytest.fixture(scope="module")
def base():
    full = hospital_tables(N_ROWS, seed=7)["patient_info"]
    data = {c: np.asarray(full.column(c)) for c in full.names}
    sc = StandardScaler(FEATS).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=5),
                    PipelineMetadata(name="m", task="regression"))
    pipe.fit({k: data[k] for k in FEATS}, data["length_of_stay"])
    store = ModelStore()
    store.register_table("patient_info", full)
    store.register_model("m", pipe)
    return store, full, pipe


def _sub(full: Table, lo: int, n: int) -> Table:
    return Table({k: v[lo:lo + n] for k, v in full.columns.items()},
                 full.valid[lo:lo + n], full.schema)


def _manual_service(store, clock, jit=False, **cfg):
    defaults = dict(latency_budget_s=1.0, min_bucket_rows=BUCKET,
                    background=False)
    defaults.update(cfg)
    return PredictionService(store, jit=jit, clock=clock,
                             admission=AdmissionConfig(**defaults))


# ---------------------------------------------------------------------------
# 1. Deterministic admission semantics (fake clock, no threads)
# ---------------------------------------------------------------------------

def test_deadline_flush_with_fake_clock(base):
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock)
    t1 = svc.submit(SQL, {"patient_info": _sub(full, 0, 20)})
    assert svc.admission_tick() == 0          # budget not yet expired
    clock.advance(0.5)
    assert svc.admission_tick() == 0          # still inside the budget
    t2 = svc.submit(SQL, {"patient_info": _sub(full, 20, 30)})
    clock.advance(0.6)                        # oldest is now 1.1s old
    assert svc.admission_tick() == 2          # one coalesced flush
    assert t1.result(timeout=0).capacity == 20
    assert t2.result(timeout=0).capacity == 30
    assert svc.stats.deadline_flushes == 1
    assert svc.stats.batch_executions == 1
    assert svc.stats.coalesced_requests == 1


def test_younger_request_does_not_extend_oldest_deadline(base):
    """The flush deadline belongs to the *oldest* request in the group —
    late arrivals ride along, they never push the deadline out."""
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock)
    svc.submit(SQL, {"patient_info": _sub(full, 0, 10)})
    clock.advance(0.99)
    svc.submit(SQL, {"patient_info": _sub(full, 10, 10)})   # 0.99s younger
    clock.advance(0.02)                       # oldest expired, younger not
    assert svc.admission_tick() == 2          # flushed together regardless
    assert svc.stats.deadline_flushes == 1


def test_full_group_flushes_without_deadline(base):
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock, max_batch_requests=3)
    tickets = [svc.submit(SQL, {"patient_info": _sub(full, 10 * i, 10)})
               for i in range(3)]
    assert svc.admission_tick() == 3          # no clock advance needed
    assert svc.stats.size_flushes == 1
    assert svc.stats.deadline_flushes == 0
    for i, t in enumerate(tickets):
        assert t.result(timeout=0).capacity == 10


def test_admit_and_flush_event_hooks(base):
    """The Batcher's event seam: every admission and every group release
    (with its reason) is observable synchronously — the contract the
    deterministic harness rests on."""
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock, max_batch_requests=2)
    admitted, flushed = [], []
    svc.batcher.on_admit = admitted.append
    svc.batcher.on_flush = \
        lambda key, items, reason: flushed.append((len(items), reason))
    svc.submit(SQL, {"patient_info": _sub(full, 0, 5)})
    assert len(admitted) == 1 and not flushed
    svc.submit(SQL, {"patient_info": _sub(full, 5, 5)})     # group now full
    assert svc.admission_tick() == 2
    assert flushed == [(2, "full")]
    svc.submit(SQL, {"patient_info": _sub(full, 0, 5)})
    clock.advance(1.5)
    svc.admission_tick()
    assert flushed[-1] == (1, "deadline")
    svc.submit(SQL, {"patient_info": _sub(full, 0, 5)})
    svc.flush()
    assert flushed[-1] == (1, "drain")
    assert len(admitted) == 4


def test_backpressure_rejects_over_bound(base):
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock, max_queue=2, block_on_full=False)
    svc.submit(SQL, {"patient_info": _sub(full, 0, 5)})
    svc.submit(SQL, {"patient_info": _sub(full, 5, 5)})
    with pytest.raises(AdmissionQueueFull):
        svc.submit(SQL, {"patient_info": _sub(full, 10, 5)})
    assert svc.stats.queue_rejections == 1
    assert svc.flush() == 2                   # bounded work still serves
    # space freed: admission works again
    t = svc.submit(SQL, {"patient_info": _sub(full, 10, 5)})
    svc.flush()
    assert t.result(timeout=0).capacity == 5


def test_blocking_offer_times_out_on_wall_clock(base):
    """A full queue with block_on_full=True must raise after the wall-time
    offer timeout even under a ManualClock that never advances — the fake
    clock drives deadlines, never how long a producer really blocks."""
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock, max_queue=1, block_on_full=True,
                          offer_timeout_s=0.05)
    svc.submit(SQL, {"patient_info": _sub(full, 0, 5)})
    with pytest.raises(AdmissionQueueFull):
        svc.submit(SQL, {"patient_info": _sub(full, 5, 5)})
    assert svc.flush() == 1


def test_legacy_mode_queue_effectively_unbounded(base):
    """Regression: without an admission config, the PR-1 contract holds —
    a single thread may queue arbitrarily many requests before its own
    flush() (only that thread could ever drain the queue, so any real
    bound would deadlock-then-reject it)."""
    store, full, _ = base
    svc = PredictionService(store, jit=False)
    assert svc.batcher.config.max_queue >= 1 << 32
    tickets = [svc.submit(SQL, {"patient_info": _sub(full, 0, 4)})
               for _ in range(40)]
    assert svc.flush() == 40
    assert all(t.done for t in tickets)


def test_queue_latency_percentiles_from_fake_clock(base):
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock)
    svc.submit(SQL, {"patient_info": _sub(full, 0, 5)})
    clock.advance(0.2)
    svc.submit(SQL, {"patient_info": _sub(full, 5, 5)})
    clock.advance(0.9)                        # waits: 1.1s and 0.9s
    svc.admission_tick()
    info = svc.admission_info()
    assert info["queue_p50_ms"] == pytest.approx(900.0)
    assert info["queue_p95_ms"] == pytest.approx(1100.0)
    assert info["coalesce_rate"] == pytest.approx(0.5)


def test_adaptive_budget_shrinks_under_light_load(base):
    """SLO-aware flush window: with one lone request (queue-depth EWMA of
    1 against a 64-request batch cap) the effective budget sits just above
    the configured *minimum* — the request is served almost immediately
    where the fixed 1s budget would have parked it."""
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock, adaptive_latency=True,
                          min_latency_budget_s=0.01,
                          max_latency_budget_s=0.65,
                          adaptive_alpha=1.0, max_batch_requests=64)
    assert svc.admission_info()["latency_budget_s"] == \
        pytest.approx(0.01)                       # idle: min budget
    svc.submit(SQL, {"patient_info": _sub(full, 0, 5)})
    budget = svc.admission_info()["latency_budget_s"]
    assert budget == pytest.approx(0.01 + 0.64 / 64)
    clock.advance(0.005)
    assert svc.admission_tick() == 0              # inside even the min
    clock.advance(0.03)                           # past the shrunk window
    assert svc.admission_tick() == 1
    assert svc.stats.deadline_flushes == 1


def test_adaptive_budget_grows_as_queue_deepens(base):
    """A deepening queue slides the window toward the max budget: the
    same elapsed wait that flushes under light load keeps coalescing
    under heavy load."""
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock, adaptive_latency=True,
                          min_latency_budget_s=0.01,
                          max_latency_budget_s=0.65,
                          adaptive_alpha=1.0, max_batch_requests=16)
    for i in range(8):                            # EWMA(alpha=1) -> depth 8
        svc.submit(SQL, {"patient_info": _sub(full, 5 * i, 5)})
    info = svc.admission_info()
    assert info["queue_depth_ewma"] == pytest.approx(8.0)
    assert info["latency_budget_s"] == pytest.approx(0.01 + 0.64 * 0.5)
    clock.advance(0.05)                           # light-load flush point
    assert svc.admission_tick() == 0              # still coalescing
    clock.advance(0.30)
    assert svc.admission_tick() == 8              # grown window expired
    assert svc.stats.deadline_flushes == 1
    # queue drained: the EWMA decays toward idle and the window shrinks
    assert svc.admission_info()["latency_budget_s"] < 0.33


def test_adaptive_window_inverted_raises(base):
    store, _, _ = base
    with pytest.raises(ValueError):
        _manual_service(store, ManualClock(), adaptive_latency=True,
                        min_latency_budget_s=0.5, max_latency_budget_s=0.1)


# ---------------------------------------------------------------------------
# 2. Bucketed-padded execution is bit-exact vs natural-shape execution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [0, 1, BUCKET - 1, BUCKET, BUCKET + 1,
                               2 * BUCKET, 2 * BUCKET + 1, 4 * BUCKET - 1])
def test_bucketed_bit_exact_vs_natural_shape(base, assert_tables_equal, n):
    """An n-row request served through pad-to-bucket + trim equals the same
    rows served at their natural shape (as a catalog table), including
    n=0, n=1, exact bucket boundaries, and boundaries±1.

    Deliberate mirror of the hypothesis property
    ``test_serving_properties.test_bucketed_padded_bit_exact`` (random row
    counts): hypothesis is an optional dependency, so that whole module
    importorskips away on minimal installs — these named edges keep the
    bucketing contract exercised everywhere.  Change both together."""
    store_full, full, pipe = base
    rows = _sub(full, 0, n)
    # natural-shape reference: the rows ARE the catalog table, so the
    # catalog path executes them unpadded
    ref_store = ModelStore()
    ref_store.register_table("patient_info", rows)
    ref_store.register_model("m", pipe)
    opt = OptimizerConfig(enable_stats_pruning=False)
    want = PredictionService(ref_store, jit=False,
                             optimizer_config=opt).run(SQL)

    clock = ManualClock()
    svc = _manual_service(store_full, clock, jit=False)
    svc.optimizer_config = opt
    got = svc.submit(SQL, {"patient_info": rows})
    svc.flush()
    assert_tables_equal(got.result(timeout=0), want)


def test_stacked_group_bit_exact_and_coalesced(base, assert_tables_equal):
    """A coalesced group spanning several sizes splits back to per-request
    results identical to serving each request alone."""
    store, full, _ = base
    spans = [(0, 1), (1, BUCKET), (9, BUCKET + 3), (30, 2 * BUCKET + 1)]
    clock = ManualClock()
    svc = _manual_service(store, clock)
    tickets = [svc.submit(SQL, {"patient_info": _sub(full, lo, n)})
               for lo, n in spans]
    clock.advance(2.0)
    assert svc.admission_tick() == len(spans)
    assert svc.stats.batch_executions == 1
    assert svc.stats.coalesced_requests == len(spans) - 1
    solo = PredictionService(store, jit=False)
    for t, (lo, n) in zip(tickets, spans):
        want = solo.run(SQL, {"patient_info": _sub(full, lo, n)})
        assert_tables_equal(t.result(timeout=0), want)


# ---------------------------------------------------------------------------
# 3. Bounded compiles: signature misses vs shape recompiles are split
# ---------------------------------------------------------------------------

def test_compiles_bounded_by_bucket_count(base):
    """Regression for the conflated executable-cache stats: batch-size
    driven recompiles must count as ``bucket_compiles`` (bounded by the
    number of pow-2 buckets), never inflate signature ``cache_misses`` —
    and actual jit traces must equal the bucket count, proving padding
    really holds shapes to O(log max_batch)."""
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock, jit=True)     # traces are the point
    codegen.reset_compile_stats()
    sizes = [1, 2, 3, 5, 7, 8, 9, 12, 15, 16, 17, 25, 31, 32, 33]
    for n in sizes:
        t = svc.submit(SQL, {"patient_info": _sub(full, 0, n)})
        svc.flush()
        t.result(timeout=0)
    buckets = {max(BUCKET, 1 << (int(n) - 1).bit_length()) for n in sizes}
    assert svc.stats.cache_misses == 1                # one signature, once
    assert svc.stats.bucket_compiles == len(buckets)  # 8, 16, 32, 64
    assert svc.stats.bucket_hits == len(sizes) - len(buckets)
    assert svc.stats.jit_traces == len(buckets)
    assert codegen.compile_stats["jit_traces"] == len(buckets)
    # repeat sweep: all warm — zero new compiles of any kind
    for n in sizes:
        t = svc.submit(SQL, {"patient_info": _sub(full, 0, n)})
        svc.flush()
        t.result(timeout=0)
    assert svc.stats.cache_misses == 1
    assert svc.stats.bucket_compiles == len(buckets)
    assert svc.stats.jit_traces == len(buckets)
    info = svc.admission_info()
    assert info["bucket_hit_rate"] == pytest.approx(
        1 - len(buckets) / (2 * len(sizes)))


def test_bucket_lookups_stay_out_of_signature_counters(base):
    """The CostAwareCache-level half of the split: bucket lookups use
    ``count=False``, so the executable cache's hit/miss ledger keeps
    meaning 'signature reuse'."""
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock)
    for n in (3, 9, 20, 3, 9, 20):
        t = svc.submit(SQL, {"patient_info": _sub(full, 0, n)})
        svc.flush()
        t.result(timeout=0)
    # cache-level: 1 signature miss + 5 signature hits; bucket lookups
    # (3 misses + 3 hits at the bucket layer) must not appear here
    assert svc._exec_cache.misses == 1
    assert svc._exec_cache.hits == 5
    assert svc.stats.bucket_compiles == 3
    assert svc.stats.bucket_hits == 3


def test_oversize_group_releases_in_capped_chunks(base):
    """max_batch_requests bounds *execution* batch size, not just flush
    timing: a burst that accumulated behind a slow execution must split
    into capped chunks, never stack as one giant padded batch."""
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock, max_batch_requests=4)
    tickets = [svc.submit(SQL, {"patient_info": _sub(full, 3 * i, 3)})
               for i in range(10)]
    clock.advance(2.0)
    assert svc.admission_tick() == 10
    assert svc.stats.batch_executions == 3          # ceil(10 / 4)
    assert svc.stats.coalesced_requests == 7
    for i, t in enumerate(tickets):
        assert t.result(timeout=0).capacity == 3


def test_full_release_holds_subcap_tail_until_its_deadline(base):
    """Tail policy: a cap-overflowing group's "full" release pops whole
    cap-sized chunks only — the sub-cap tail (the *newest* requests) stays
    queued to coalesce with the next burst instead of executing a
    near-empty padded batch.  The tail still honors its own latency
    budget, and later admissions can complete it into a full chunk."""
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock, max_batch_requests=4)
    tickets = [svc.submit(SQL, {"patient_info": _sub(full, 3 * i, 3)})
               for i in range(6)]
    # full trigger at t=0: one capped chunk of 4 releases, tail of 2 holds
    assert svc.admission_tick() == 4
    assert svc.stats.size_flushes == 1
    assert all(t.done for t in tickets[:4])
    assert not any(t.done for t in tickets[4:])
    # not due yet: the tail keeps waiting inside its own budget
    clock.advance(0.5)
    assert svc.admission_tick() == 0
    # two more arrivals complete the tail into a full chunk -> releases
    tickets += [svc.submit(SQL, {"patient_info": _sub(full, 0, 3)})
                for _ in range(2)]
    assert svc.admission_tick() == 4
    assert svc.stats.size_flushes == 2
    assert all(t.done for t in tickets)
    # a tail nothing completes releases at its own deadline instead
    tail = [svc.submit(SQL, {"patient_info": _sub(full, 0, 3)})
            for _ in range(5)]
    assert svc.admission_tick() == 4                # full chunk, 1 held
    assert not tail[4].done
    clock.advance(1.0)                              # tail's budget expires
    assert svc.admission_tick() == 1
    assert svc.stats.deadline_flushes == 1
    assert tail[4].done
    # drain still leaves nothing behind
    svc.submit(SQL, {"patient_info": _sub(full, 0, 3)})
    assert svc.flush() == 1
    svc.close()


def test_results_device_backed_regardless_of_row_count(base):
    """Every serving path returns the same device-array-backed tables
    PR 1 did — the result type must not flip to numpy when the row count
    happens to miss the padded bucket boundary."""
    import jax
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock)
    for n in (5, BUCKET, BUCKET + 3):               # off/on/off boundary
        t = svc.submit(SQL, {"patient_info": _sub(full, 0, n)})
        svc.flush()
        out = t.result(timeout=0)
        assert all(isinstance(v, jax.Array) for v in out.columns.values()), \
            f"n={n} returned non-device columns"
        assert isinstance(out.valid, jax.Array)


def test_catalog_group_shares_one_execution_beyond_cap(base):
    """max_batch_requests never splits identical-catalog-table groups:
    they share ONE execution however many coalesce (splitting would only
    multiply full-plan executions), the cap just triggers their flush."""
    store, full, _ = base
    clock = ManualClock()
    svc = _manual_service(store, clock, max_batch_requests=4)
    tickets = [svc.submit(SQL) for _ in range(10)]
    clock.advance(2.0)
    assert svc.admission_tick() == 10
    assert svc.stats.batch_executions == 1
    assert svc.stats.coalesced_requests == 9
    v0 = np.asarray(tickets[0].result(timeout=0).valid)
    assert (v0 == np.asarray(tickets[-1].result(timeout=0).valid)).all()


@pytest.mark.timeout_guard(120)
def test_loop_service_is_garbage_collectible(base):
    """A dropped (unclosed) service must not leak: the loop thread holds
    only weak callbacks, a finalizer stops it, and the catalog
    invalidation listener detaches — close() stays the orderly path but
    forgetting it costs nothing permanent."""
    import gc
    import time
    import weakref as wr
    store, full, _ = base
    gc.collect()            # flush listeners of earlier tests' dead services
    n_listeners = len(store._invalidation_listeners)
    svc = PredictionService(store, jit=False, admission=AdmissionConfig(
        latency_budget_s=0.01, min_bucket_rows=BUCKET))
    svc.run(SQL, {"patient_info": _sub(full, 0, 5)})
    loop_thread = svc._loop._thread
    ref = wr.ref(svc)
    del svc
    # the loop thread's serve frame may still hold a transient strong ref
    # (the weak callback upgrades for the duration of one call) — only a
    # *lasting* pin is a leak
    deadline = time.time() + 10
    gc.collect()
    while ref() is not None and time.time() < deadline:
        time.sleep(0.05)
        gc.collect()
    assert ref() is None, "admission loop pinned the service against GC"
    loop_thread.join(timeout=10)
    assert not loop_thread.is_alive(), "loop thread leaked after GC"
    gc.collect()
    assert len(store._invalidation_listeners) == n_listeners


def test_bucket_twin_tagged_even_after_self_eviction(base):
    """Regression: under a full cache the twin's zero-cost initial insert
    self-evicts and the post-execution cost re-put re-creates the entry —
    it must carry the model/table tags, or register_model invalidation
    could never reach it (a stale untagged executable pinned forever)."""
    store, full, pipe = base
    clock = ManualClock()
    svc = PredictionService(
        store, jit=False, clock=clock, max_cache_entries=1,
        admission=AdmissionConfig(latency_budget_s=1.0,
                                  min_bucket_rows=BUCKET, background=False))
    t = svc.submit(SQL, {"patient_info": _sub(full, 0, 5)})
    svc.flush()
    t.result(timeout=0)
    entries = [svc._exec_cache.entry(k) for k in svc._exec_cache.keys()]
    assert entries and all(("model", "m") in e.tags for e in entries)
    store.register_model("m", pipe)          # re-register fires invalidation
    assert len(svc._exec_cache) == 0
    assert svc.stats.invalidation_evictions >= 1
    svc.close()


# ---------------------------------------------------------------------------
# 4. Background loop: threads, drain-on-close, ticket timeout
# ---------------------------------------------------------------------------

@pytest.mark.timeout_guard(180)
def test_loop_serves_within_budget_and_coalesces(base):
    store, full, _ = base
    svc = PredictionService(store, jit=False, admission=AdmissionConfig(
        latency_budget_s=0.05, min_bucket_rows=BUCKET))
    try:
        barrier = threading.Barrier(4)
        results = {}

        def worker(i):
            barrier.wait(timeout=30)
            t = svc.submit(SQL, {"patient_info": _sub(full, 10 * i, 10)})
            results[i] = t.result(timeout=60)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=90)
            assert not t.is_alive(), "worker deadlocked"
        assert len(results) == 4
        assert all(results[i].capacity == 10 for i in range(4))
        # the barrier puts all 4 in flight inside one budget window: they
        # must not have executed one-by-one
        assert svc.stats.coalesced_requests >= 1
        assert svc.stats.batch_executions < 4
    finally:
        svc.close()


@pytest.mark.timeout_guard(300)
def test_loop_ledger_invariants_under_stress(base):
    """8 threads x 8 requests against a live admission loop: every ticket
    resolves exactly once (double-resolution raises inside _resolve),
    nothing is lost, and requests == executions + coalesced."""
    store, full, _ = base
    svc = PredictionService(store, jit=False, admission=AdmissionConfig(
        latency_budget_s=0.01, min_bucket_rows=BUCKET, max_queue=64))
    n_threads, per_thread = 8, 8
    errors, results = [], {}
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        try:
            barrier.wait(timeout=30)
            for i in range(per_thread):
                lo = (7 * tid + 3 * i) % (N_ROWS - 40)
                n = 1 + (tid + 5 * i) % 30
                t = svc.submit(SQL, {"patient_info": _sub(full, lo, n)})
                out = t.result(timeout=120)
                assert out.capacity == n
                results[(tid, i)] = out
        except Exception as e:                 # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=240)
        assert not t.is_alive(), "worker deadlocked"
    svc.close()
    assert not errors
    assert len(results) == n_threads * per_thread
    s = svc.stats
    assert s.submitted == n_threads * per_thread
    assert s.batch_executions + s.coalesced_requests == s.submitted
    assert s.cache_hits + s.cache_misses == s.batch_executions
    # shape discipline held under concurrency too
    assert s.bucket_compiles <= 9             # buckets possible up to 2^8
    info = svc.admission_info()
    assert info["queue_depth"] == 0


@pytest.mark.timeout_guard(120)
def test_close_drains_in_flight_without_deadlock(base):
    store, full, _ = base
    svc = PredictionService(store, jit=False, admission=AdmissionConfig(
        latency_budget_s=30.0, min_bucket_rows=BUCKET))   # loop won't fire
    tickets = [svc.submit(SQL, {"patient_info": _sub(full, 5 * i, 5)})
               for i in range(6)]
    assert not any(t.done for t in tickets)
    svc.close()                                # must drain, not deadlock
    for t in tickets:
        assert t.result(timeout=0).capacity == 5
    assert svc.stats.drain_flushes >= 1
    assert not svc.admission_info()["background_loop"]


@pytest.mark.timeout_guard(120)
def test_ticket_timeout_raises_while_loop_running(base):
    """Regression: with the admission loop alive but the budget far away,
    ``result(timeout=...)`` must raise TimeoutError — not block, not
    return None."""
    store, full, _ = base
    svc = PredictionService(store, jit=False, admission=AdmissionConfig(
        latency_budget_s=30.0, min_bucket_rows=BUCKET))
    try:
        ticket = svc.submit(SQL, {"patient_info": _sub(full, 0, 10)})
        with pytest.raises(TimeoutError):
            ticket.result(timeout=0.05)
        assert not ticket.done
    finally:
        svc.close()
    assert ticket.result(timeout=0).capacity == 10      # drained by close


@pytest.mark.timeout_guard(120)
def test_loop_escape_fails_tickets_instead_of_stranding(base):
    """An error escaping the serve callback (past _serve_group's own
    handlers) must fail the group's tickets via the loop's on_error hook
    — a caller blocked in result() must never hang on a harness bug —
    and surface as admission_info()['loop_error']."""
    store, full, _ = base
    svc = PredictionService(store, jit=False, admission=AdmissionConfig(
        latency_budget_s=0.01, min_bucket_rows=BUCKET))
    try:
        def boom(key, group):
            raise RuntimeError("injected harness bug")
        svc._serve_group = boom            # escapes _serve_ready untouched
        ticket = svc.submit(SQL, {"patient_info": _sub(full, 0, 5)})
        with pytest.raises(RuntimeError, match="injected harness bug"):
            ticket.result(timeout=30)
        assert isinstance(svc.admission_info()["loop_error"], RuntimeError)
    finally:
        del svc._serve_group               # restore class method for close()
        svc.close()


def test_pow2_bucket_respects_non_pow2_max(base):
    """Regression: a non-power-of-two max_rows is a hard cap — doubling
    must not overshoot it for n under the cap (device-memory ceilings)."""
    from repro.core.codegen import pow2_bucket
    assert pow2_bucket(80, min_rows=64, max_rows=100) == 100
    assert pow2_bucket(100, min_rows=64, max_rows=100) == 100
    assert pow2_bucket(101, min_rows=64, max_rows=100) == 200
    # monotone around the cap
    assert pow2_bucket(100, 64, 100) <= pow2_bucket(101, 64, 100)


def test_submit_after_close_raises(base):
    store, full, _ = base
    svc = PredictionService(store, jit=False, admission=AdmissionConfig(
        latency_budget_s=0.01, min_bucket_rows=BUCKET))
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit(SQL, {"patient_info": _sub(full, 0, 5)})


def test_explicit_flush_mode_unchanged_by_refactor(base):
    """The PR-1 contract survives the Batcher refactor: without an
    admission config, requests wait for flush() regardless of clock."""
    store, full, _ = base
    svc = PredictionService(store, jit=False)
    t = svc.submit(SQL, {"patient_info": _sub(full, 0, 10)})
    with pytest.raises(TimeoutError):
        t.result(timeout=0.02)
    assert svc.flush() == 1
    assert t.result(timeout=0).capacity == 10
