"""Beyond-paper features: cost-based choices, subplan dedup, lossy pushdown."""

import numpy as np
import pytest

from repro.core import (CrossOptimizer, OptimizerConfig, execute,
                        parse_query)
from repro.core.cost_model import (CostParams, choose_tree_impl,
                                   estimate_rows, tree_impl_costs)
from repro.ml import DecisionTree, RandomForest


def _toy_tree(depth=8, n=2000, d=6, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x[:, 0] + x[:, 1] > 0).astype(np.int32)
    return DecisionTree(max_depth=depth, min_leaf=2).fit(x, y)


def test_cost_model_prefers_traversal_on_cpu():
    dt = _toy_tree()
    cpu = CostParams.for_backend("cpu")
    assert choose_tree_impl(dt, 1e6, 6, cpu) in ("traversal", "inline_case")


def test_cost_model_prefers_gemm_on_tpu_for_forests():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(1500, 6)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.int32)
    rf = RandomForest(n_trees=16, max_depth=7).fit(x, y)
    tpu = CostParams.for_backend("tpu")
    assert choose_tree_impl(rf, 1e6, 6, tpu) == "gemm"


def test_cost_model_inline_for_small_trees():
    dt = _toy_tree(depth=3)
    cpu = CostParams.for_backend("cpu")
    costs = tree_impl_costs(dt.model if hasattr(dt, "model") else dt,
                            1e5, 6, cpu)
    # a 3-deep tree has ~15 nodes: CASE cost ~ nodes*c_cmp < traversal
    assert costs["inline_case"] < costs["gemm"]


def test_estimate_rows_uses_stats(hospital_tree):
    store, data, _ = hospital_tree
    plan = parse_query(
        "SELECT pid FROM patient_info WHERE pregnant = 1", store)
    rows = estimate_rows(plan, store)
    filt = next(n for n in plan.nodes.values() if n.op == "filter")
    scan = next(n for n in plan.nodes.values() if n.op == "scan")
    assert rows[filt.id] < rows[scan.id]
    assert rows[filt.id] == pytest.approx(rows[scan.id] / 2, rel=0.01)


def test_cost_based_optimizer_preserves_semantics(hospital_tree):
    store, data, pipe = hospital_tree
    sql = ("SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
           "JOIN blood_tests ON pid WHERE age > 40")
    plan = parse_query(sql, store)
    oplan, rep = CrossOptimizer(store, OptimizerConfig(
        cost_based=True)).optimize(plan)
    a = execute(plan, store).to_pydict()
    b = execute(oplan, store).to_pydict()
    assert a["pid"] == b["pid"]
    assert np.allclose(a["los"], b["los"], atol=1e-4)


def test_subplan_dedup_merges_shared_featurize(hospital_tree):
    store, data, pipe = hospital_tree
    sql = ("SELECT pid, PREDICT(MODEL='los') AS los, "
           "PREDICT_PROBA(MODEL='los') AS p "
           "FROM patient_info JOIN blood_tests ON pid")
    plan = parse_query(sql, store)
    n_feat_before = len([n for n in plan.nodes.values()
                         if n.op == "featurize"])
    assert n_feat_before == 2           # one per PREDICT flavor
    cfg = OptimizerConfig(enable_model_inlining=False,
                          enable_nn_translation=False,
                          enable_model_pruning=False,
                          enable_projection_pushdown=False)
    oplan, rep = CrossOptimizer(store, cfg).optimize(plan)
    assert rep.fired("subplan_dedup")
    n_feat_after = len([n for n in oplan.nodes.values()
                        if n.op == "featurize"])
    assert n_feat_after == 1
    a = execute(plan, store).to_pydict()
    b = execute(oplan, store).to_pydict()
    assert a["pid"] == b["pid"]
    assert np.allclose(a["p"], b["p"], atol=1e-5)


def test_lossy_pushdown_flag(flights):
    store, fcols, fy, pipe = flights
    sql = "SELECT dep_hour, PREDICT(MODEL='delay') AS cls FROM flights"
    plan = parse_query(sql, store)
    exact, _ = CrossOptimizer(store, OptimizerConfig()).optimize(plan)
    lossy, rep = CrossOptimizer(store, OptimizerConfig(
        lossy_pushdown_tol=0.05)).optimize(plan)
    def n_features(p):
        f = next(n for n in p.nodes.values() if n.op == "featurize")
        return sum(x.mapping().n_features for x in f.attrs["featurizers"])
    assert n_features(lossy) <= n_features(exact)
    a = np.asarray(execute(plan, store).to_pydict()["cls"])
    b = np.asarray(execute(lossy, store).to_pydict()["cls"])
    assert (a == b).mean() > 0.95       # lossy but close
