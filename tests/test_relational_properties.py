"""Property-based tests: relational operators vs a numpy oracle.

The system invariant: mask-carrying static-shape execution must agree with
plain compacting numpy semantics (SQL bags) for every operator composition.
"""

import os

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.relational import (Table, col, const, filter_, group_aggregate,
                              join_unique, limit, order_by, union_all)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile(
    os.environ.get("HYPOTHESIS_PROFILE", "ci"))


@st.composite
def table_data(draw, min_rows=1, max_rows=40):
    n = draw(st.integers(min_rows, max_rows))
    ints = draw(st.lists(st.integers(-5, 5), min_size=n, max_size=n))
    floats = draw(st.lists(
        st.floats(-100, 100, allow_nan=False, width=32),
        min_size=n, max_size=n))
    cats = draw(st.lists(st.integers(0, 3), min_size=n, max_size=n))
    return {"a": np.asarray(ints, np.int32),
            "x": np.asarray(floats, np.float32),
            "g": np.asarray(cats, np.int32)}


@given(table_data(), st.integers(-5, 5))
def test_filter_matches_numpy(data, threshold):
    t = Table.from_pydict(data)
    out = filter_(t, col("a") > threshold)
    got = out.to_pydict()
    keep = data["a"] > threshold
    assert got["a"] == data["a"][keep].tolist()
    assert np.allclose(got["x"], data["x"][keep], atol=1e-5)


@given(table_data(), st.integers(-5, 5), st.integers(0, 3))
def test_conjunctive_filter(data, thr_a, thr_g):
    t = Table.from_pydict(data)
    out = filter_(t, (col("a") > thr_a) & (col("g") == thr_g))
    keep = (data["a"] > thr_a) & (data["g"] == thr_g)
    assert out.to_pydict()["a"] == data["a"][keep].tolist()


@given(table_data())
def test_group_aggregate_matches_numpy(data):
    t = Table.from_pydict(data)
    out = group_aggregate(t, "g", {"s": ("sum", "x"), "n": ("count", None),
                                   "m": ("avg", "x")}, num_groups=4)
    got = out.to_pydict()
    for i, gval in enumerate(got["g"]):
        mask = data["g"] == gval
        assert mask.sum() == got["n"][i]
        assert np.isclose(got["s"][i], data["x"][mask].sum(), atol=1e-2)
        assert np.isclose(got["m"][i], data["x"][mask].mean(), atol=1e-3)


@given(table_data())
def test_global_aggregate(data):
    t = Table.from_pydict(data)
    out = group_aggregate(t, None, {"mx": ("max", "x"), "mn": ("min", "x"),
                                    "n": ("count", None)})
    got = out.to_pydict()
    assert got["n"] == [len(data["x"])]
    assert np.isclose(got["mx"][0], data["x"].max(), atol=1e-5)
    assert np.isclose(got["mn"][0], data["x"].min(), atol=1e-5)


@given(table_data(min_rows=2))
def test_order_by_limit(data):
    t = Table.from_pydict(data)
    out = limit(order_by(t, "x", descending=True), 3)
    got = out.to_pydict()["x"]
    ref = sorted(data["x"].tolist(), reverse=True)[:3]
    assert np.allclose(sorted(got, reverse=True), ref, atol=1e-5)


@given(st.integers(2, 30), st.integers(2, 30), st.integers(0, 100))
def test_join_unique_matches_numpy(n_left, n_right, seed):
    rng = np.random.default_rng(seed)
    # right side: unique keys
    rkeys = rng.permutation(50)[:n_right].astype(np.int32)
    lkeys = rng.choice(50, n_left).astype(np.int32)
    left = Table.from_pydict({"k": lkeys,
                              "lv": np.arange(n_left, dtype=np.float32)})
    right = Table.from_pydict({"k": rkeys,
                               "rv": rng.normal(size=n_right)
                               .astype(np.float32)})
    out = join_unique(left, right, on="k").to_pydict()
    rmap = {int(k): float(v) for k, v in zip(rkeys, right.to_pydict()["rv"])}
    exp_keys = [int(k) for k in lkeys if int(k) in rmap]
    assert out["k"] == exp_keys
    assert np.allclose(out["rv"], [rmap[k] for k in exp_keys], atol=1e-5)


@given(table_data(), table_data())
def test_union_all_counts(d1, d2):
    t = union_all(Table.from_pydict(d1), Table.from_pydict(d2))
    assert int(t.num_valid()) == len(d1["a"]) + len(d2["a"])


@given(table_data(), st.integers(-5, 5))
def test_filter_after_union_commutes(data, thr):
    t1 = Table.from_pydict(data)
    t2 = Table.from_pydict(data)
    pred = col("a") > thr
    a = filter_(union_all(t1, t2), pred).to_pydict()
    b = union_all(filter_(t1, pred), filter_(t2, pred)).to_pydict()
    assert a["a"] == b["a"]
