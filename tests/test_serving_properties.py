"""Property-based serving-layer battery (hypothesis).

Three guarantees the serving layer leans on, tested as *properties* rather
than single examples:

(a) ``plan_signature`` is a pure function of plan *structure + content*:
    invariant under node-id renumbering and attr-dict insertion order,
    sensitive to model-content (weight) changes;
(b) chunked/morsel execution is bit-exact vs whole-table execution for any
    row count — empty tables, exact chunk multiples, single-row tails;
(c) stacked micro-batch execution equals per-request sequential execution
    for randomized same-signature request groups;
(d) bucketed-padded execution (continuous batching's shape buckets) is
    bit-exact vs natural-shape execution for any row count — 0, 1, exact
    power-of-two bucket boundaries, and boundaries±1.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, example, given, settings, strategies as st

from repro.core import ModelStore
from repro.core.ir import Category, Node, Plan, plan_signature
from repro.core.model_store import content_fingerprint
from repro.data import hospital_tables
from repro.ml import DecisionTree, Pipeline, PipelineMetadata, StandardScaler
from repro.relational.expr import col
from repro.relational.table import Table
from repro.serve import PredictionService

pytestmark = pytest.mark.tier1

N_ROWS = 600
FEATS = ["age", "gender", "pregnant", "rcount"]
SQL = "SELECT pid, PREDICT(MODEL='m') AS p FROM patient_info WHERE age > 30"


@pytest.fixture(scope="module")
def base():
    full = hospital_tables(N_ROWS, seed=7)["patient_info"]
    data = {c: np.asarray(full.column(c)) for c in full.names}
    sc = StandardScaler(FEATS).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=5),
                    PipelineMetadata(name="m", task="regression"))
    pipe.fit({k: data[k] for k in FEATS}, data["length_of_stay"])
    return full, pipe


def _sub_table(full: Table, lo: int, n: int) -> Table:
    return Table({k: v[lo:lo + n] for k, v in full.columns.items()},
                 full.valid[lo:lo + n], full.schema)


# ---------------------------------------------------------------------------
# (a) plan-signature properties
# ---------------------------------------------------------------------------

class _Model:
    """Minimal model-like artifact: content is one weight array."""

    def __init__(self, w):
        self.w = np.asarray(w, np.float32)


def _build_plan(ids, attr_order, threshold, weights) -> Plan:
    """The same logical plan under caller-chosen node ids and attr-dict
    insertion orders."""
    plan = Plan()
    scan = plan.add(Node("scan", Category.RA, [],
                         {"table": "patient_info"}, "table", id=ids[0]))
    filt = plan.add(Node("filter", Category.RA, [scan],
                         {"predicate": col("age") > threshold}, "table",
                         id=ids[1]))
    attrs = {"model": _Model(weights), "task": "regression", "proba": False}
    if attr_order:
        attrs = dict(reversed(list(attrs.items())))
    pred = plan.add(Node("predict_model", Category.MLD, [filt], attrs,
                         "vector", id=ids[2]))
    plan.output = pred
    return plan


@settings(max_examples=25, deadline=None)
@given(alias=st.text(alphabet="abcxyz", min_size=1, max_size=6),
       offset=st.integers(0, 1000),
       reorder=st.booleans(),
       threshold=st.integers(-5, 90),
       w=st.lists(st.integers(-100, 100), min_size=1, max_size=4))
def test_signature_invariant_to_ids_and_attr_order(alias, offset, reorder,
                                                   threshold, w):
    ids_a = [f"{alias}_{i}" for i in range(3)]
    ids_b = [f"zz_{alias}_{i + offset}" for i in range(3)]
    p1 = _build_plan(ids_a, False, threshold, w)
    p2 = _build_plan(ids_b, reorder, threshold, w)
    assert plan_signature(p1) == plan_signature(p2)


@settings(max_examples=25, deadline=None)
@given(threshold=st.integers(-5, 90),
       w=st.lists(st.integers(-100, 100), min_size=1, max_size=4),
       idx=st.integers(0, 3), delta=st.integers(1, 7))
def test_signature_sensitive_to_model_content(threshold, w, idx, delta):
    p1 = _build_plan(["a", "b", "c"], False, threshold, w)
    w2 = list(w)
    w2[idx % len(w2)] += delta          # guaranteed content change
    p2 = _build_plan(["a", "b", "c"], False, threshold, w2)
    assert plan_signature(p1) != plan_signature(p2)
    assert content_fingerprint(_Model(w)) != content_fingerprint(_Model(w2))


# ---------------------------------------------------------------------------
# (b) chunked == whole-table, bit-exact, any row count
# ---------------------------------------------------------------------------

CHUNK = 16


def _chunk_pair(full, pipe, n):
    store = ModelStore()
    store.register_table("patient_info", _sub_table(full, 0, n))
    store.register_model("m", pipe)
    whole = PredictionService(store, jit=False)
    chunked = PredictionService(store, jit=False, chunk_rows=CHUNK)
    return whole.run(SQL), chunked.run(SQL), chunked


@pytest.mark.parametrize("n", [0, 1, CHUNK - 1, CHUNK, CHUNK + 1,
                               3 * CHUNK, 3 * CHUNK + 1])
def test_chunked_bit_exact_named_edges(base, assert_tables_equal, n):
    """Empty table, single row, exact chunk multiples, single-row tails."""
    full, pipe = base
    o1, o2, chunked = _chunk_pair(full, pipe, n)
    assert_tables_equal(o1, o2)
    expected_chunks = 0 if n <= CHUNK else -(-n // CHUNK)
    assert chunked.stats.chunks_executed == expected_chunks


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n=st.integers(0, 6 * CHUNK + 1))
def test_chunked_bit_exact_random_row_counts(base, assert_tables_equal, n):
    full, pipe = base
    o1, o2, _ = _chunk_pair(full, pipe, n)
    assert_tables_equal(o1, o2)


# ---------------------------------------------------------------------------
# (c) stacked micro-batch == sequential per-request execution
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stack_service(base):
    full, pipe = base
    store = ModelStore()
    store.register_table("patient_info", full)
    store.register_model("m", pipe)
    return PredictionService(store, jit=False), full


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(spans=st.lists(st.tuples(st.integers(0, N_ROWS - 60),
                                st.integers(1, 60)),
                      min_size=1, max_size=5))
def test_stacked_equals_sequential(stack_service, assert_tables_equal, spans):
    service, full = stack_service
    tables = [{"patient_info": _sub_table(full, lo, n)} for lo, n in spans]
    tickets = [service.submit(SQL, t) for t in tables]
    assert service.flush() == len(tickets)
    stacked = [t.result() for t in tickets]
    sequential = [service.run(SQL, t) for t in tables]
    for got, want in zip(stacked, sequential):
        assert_tables_equal(got, want)


# ---------------------------------------------------------------------------
# (d) bucketed-padded == natural shape, bit-exact, any row count
# ---------------------------------------------------------------------------

BUCKET = 16          # continuous batching's min_bucket_rows under test


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n=st.integers(0, 4 * BUCKET + 1))
@example(n=0)                       # empty request
@example(n=1)                       # single row
@example(n=BUCKET - 1)              # bucket boundary - 1
@example(n=BUCKET)                  # exact bucket boundary
@example(n=BUCKET + 1)              # bucket boundary + 1
@example(n=2 * BUCKET)              # exact power-of-two boundary
@example(n=2 * BUCKET + 1)
def test_bucketed_padded_bit_exact(base, assert_tables_equal, n):
    """A request of any row count served through the shape-bucketed path
    (pad to pow-2 bucket, execute, trim) is bit-exact vs the same rows
    executed at their natural shape as a catalog table.

    Mirrored by the named-edge parametrization in
    ``test_continuous_batching.test_bucketed_bit_exact_vs_natural_shape``,
    which runs even where hypothesis is absent.  Change both together."""
    from repro.core import OptimizerConfig
    from repro.serve import AdmissionConfig, ManualClock

    full, pipe = base
    rows = _sub_table(full, 0, n)
    opt = OptimizerConfig(enable_stats_pruning=False)
    ref_store = ModelStore()
    ref_store.register_table("patient_info", rows)
    ref_store.register_model("m", pipe)
    want = PredictionService(ref_store, jit=False,
                             optimizer_config=opt).run(SQL)

    store = ModelStore()
    store.register_table("patient_info", full)
    store.register_model("m", pipe)
    svc = PredictionService(
        store, jit=False, optimizer_config=opt, clock=ManualClock(),
        admission=AdmissionConfig(min_bucket_rows=BUCKET, background=False))
    ticket = svc.submit(SQL, {"patient_info": rows})
    assert svc.flush() == 1
    assert_tables_equal(ticket.result(timeout=0), want)
