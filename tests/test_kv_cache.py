"""Paged KV cache: allocator invariants + attention equivalence."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.serve.kv_cache import PagedKVCache

settings.register_profile("kv", max_examples=15, deadline=None)
settings.load_profile(
    os.environ.get("HYPOTHESIS_PROFILE", "kv"))


def test_alloc_free_reuse():
    c = PagedKVCache(n_blocks=4, block=2, n_kv=1, hd=4,
                     max_blocks_per_seq=2)
    c.allocate(0)
    for _ in range(4):
        c.append(0, jnp.ones((1, 4)))
    assert c.free_blocks() == 2
    with pytest.raises(AssertionError):
        c.allocate(0)
    c.free(0)
    assert c.free_blocks() == 4


def test_pool_exhaustion():
    c = PagedKVCache(n_blocks=1, block=2, n_kv=1, hd=4,
                     max_blocks_per_seq=2)
    c.allocate(0)
    c.append(0, jnp.ones((1, 4)))
    c.append(0, jnp.ones((1, 4)))
    with pytest.raises(MemoryError):
        c.append(0, jnp.ones((1, 4)))


@given(st.lists(st.integers(1, 9), min_size=1, max_size=3),
       st.integers(0, 100))
def test_paged_attention_equals_contiguous(lengths, seed):
    """Attention over the paged gather == attention over a contiguous
    cache, for ragged sequence lengths sharing one pool."""
    rng = np.random.default_rng(seed)
    kv, hd, block = 2, 8, 4
    max_blocks = 3
    pool_blocks = max_blocks * len(lengths)
    cache_k = PagedKVCache(pool_blocks, block, kv, hd, max_blocks,
                           dtype=jnp.float32)
    cache_v = PagedKVCache(pool_blocks, block, kv, hd, max_blocks,
                           dtype=jnp.float32)
    contiguous_k = np.zeros((len(lengths), max_blocks * block, kv, hd),
                            np.float32)
    contiguous_v = np.zeros_like(contiguous_k)
    # interleave appends across sequences (fragmenting the pool)
    order = [s for s, n in enumerate(lengths) for _ in range(n)]
    rng.shuffle(order)
    pos = [0] * len(lengths)
    for s in order:
        if pos[s] == 0 and s not in cache_k.tables:
            cache_k.allocate(s)
            cache_v.allocate(s)
        kt = rng.normal(size=(kv, hd)).astype(np.float32)
        vt = rng.normal(size=(kv, hd)).astype(np.float32)
        if s not in cache_k.tables:
            cache_k.allocate(s)
            cache_v.allocate(s)
        cache_k.append(s, jnp.asarray(kt))
        cache_v.append(s, jnp.asarray(vt))
        contiguous_k[s, pos[s]] = kt
        contiguous_v[s, pos[s]] = vt
        pos[s] += 1

    sids = list(range(len(lengths)))
    pk, lens = cache_k.batch_gather(sids)
    pv, _ = cache_v.batch_gather(sids)
    q = jnp.asarray(rng.normal(size=(len(lengths), 1, kv * 2, hd)),
                    jnp.float32)
    out_paged = decode_attention_ref(q, pk, pv, lens)
    out_ref = decode_attention_ref(q, jnp.asarray(contiguous_k),
                                   jnp.asarray(contiguous_v),
                                   jnp.asarray(lengths, jnp.int32))
    np.testing.assert_allclose(np.asarray(out_paged), np.asarray(out_ref),
                               atol=1e-5)
