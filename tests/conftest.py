"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device
(the 512-device override belongs exclusively to launch/dryrun.py)."""

import faulthandler
import os

import numpy as np
import pytest

try:
    from hypothesis import settings as _hyp_settings
except ImportError:                       # hypothesis is optional locally
    _hyp_settings = None
else:
    # Raised example budget for the scheduled nightly run (nightly.yml):
    # select it with --hypothesis-profile=nightly *and* export
    # HYPOTHESIS_PROFILE=nightly — the property-test modules load their
    # own CI-sized profile at import time unless the env var names
    # another registered profile.
    _hyp_settings.register_profile("nightly", max_examples=300,
                                   deadline=None)


@pytest.fixture(autouse=True)
def _timeout_guard(request):
    """Fail-fast guard for tests that run background threads (the serving
    admission loop): a wedged loop must kill the run with stack traces
    from every thread instead of hanging tier-1 forever.  Opt in with
    ``@pytest.mark.timeout_guard(seconds)``; ``REPRO_TEST_TIMEOUT``
    (exported by scripts/verify.sh) caps the budget suite-wide.  Uses
    ``faulthandler.dump_traceback_later`` — no extra dependency, and the
    dump shows exactly which lock the loop wedged on."""
    marker = request.node.get_closest_marker("timeout_guard")
    if marker is None:
        yield
        return
    seconds = float(marker.args[0]) if marker.args else 120.0
    env_cap = os.environ.get("REPRO_TEST_TIMEOUT")
    if env_cap:
        seconds = min(seconds, float(env_cap))
    faulthandler.dump_traceback_later(seconds, exit=True)
    try:
        yield
    finally:
        faulthandler.cancel_dump_traceback_later()


@pytest.fixture(scope="session")
def assert_tables_equal():
    """Bit-exact table comparison shared by the serving-layer tests."""

    def check(a, b):
        assert (np.asarray(a.valid) == np.asarray(b.valid)).all()
        assert set(a.columns) == set(b.columns)
        for k in a.columns:
            assert (np.asarray(a.columns[k])
                    == np.asarray(b.columns[k])).all(), \
                f"column {k} diverged"

    return check


@pytest.fixture(scope="session")
def hospital():
    from repro.core import ModelStore
    from repro.data import hospital_tables
    store = ModelStore()
    tables = hospital_tables(4000, seed=7)
    for n, t in tables.items():
        store.register_table(n, t)
    data = {}
    for t in tables.values():
        for c in t.names:
            data[c] = np.asarray(t.column(c))
    return store, data


@pytest.fixture(scope="session")
def hospital_tree(hospital):
    from repro.ml import DecisionTree, Pipeline, PipelineMetadata, \
        StandardScaler
    store, data = hospital
    feat = ["age", "gender", "pregnant", "rcount", "hematocrit",
            "neutrophils", "bp"]
    sc = StandardScaler(feat).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=7,
                                       min_leaf=15),
                    PipelineMetadata(name="los", task="regression"))
    pipe.fit({k: data[k] for k in feat}, data["length_of_stay"])
    store.register_model("los", pipe)
    return store, data, pipe


@pytest.fixture(scope="session")
def flights():
    from repro.core import ModelStore
    from repro.data import flight_features
    from repro.ml import (LogisticRegression, OneHotEncoder, Pipeline,
                          PipelineMetadata, StandardScaler)
    from repro.relational import Table
    fcols, fy = flight_features(4000, seed=3)
    store = ModelStore()
    store.register_table("flights", Table.from_pydict({**fcols,
                                                       "delayed": fy}))
    ohe = OneHotEncoder(["origin", "dest", "carrier"]).fit(fcols)
    sc = StandardScaler(["distance", "taxi_out", "dep_hour"]).fit(fcols)
    pipe = Pipeline([ohe, sc], LogisticRegression(l1=0.01, steps=150),
                    PipelineMetadata(name="delay", task="classification"))
    pipe.fit(fcols, fy)
    store.register_model("delay", pipe)
    return store, fcols, fy, pipe
