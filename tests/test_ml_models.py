"""Unit + property tests for the classical ML layer."""

import os

import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.ml import (DecisionTree, GradientBoostedTrees, LinearRegression,
                      LogisticRegression, MLP, OneHotEncoder, RandomForest,
                      StandardScaler, ensemble_to_gemm, fit_tree_arrays,
                      predict_ensemble_gemm, predict_gemm, tree_to_gemm)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile(
    os.environ.get("HYPOTHESIS_PROFILE", "ci"))


def _toy(n=400, d=5, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x[:, 0] + 0.5 * x[:, 1] > 0).astype(np.int32)
    return x, y


def test_tree_jnp_matches_numpy_oracle():
    x, y = _toy()
    tree = fit_tree_arrays(x, y, "classification", max_depth=5)
    got = np.asarray(tree.predict_jnp(jnp.asarray(x)))
    ref = tree.predict_numpy(x)
    assert np.allclose(got, ref, atol=1e-6)


def test_tree_learns_signal():
    x, y = _toy(800)
    dt = DecisionTree(max_depth=5).fit(x, y)
    acc = (np.asarray(dt.predict(jnp.asarray(x))) == y).mean()
    assert acc > 0.9


@given(st.integers(0, 1000))
def test_gemm_translation_equivalence(seed):
    x, y = _toy(200, seed=seed % 7)
    tree = fit_tree_arrays(x, y, "classification", max_depth=4, min_leaf=5)
    g = tree_to_gemm(tree)
    ref = tree.predict_numpy(x)
    got = np.asarray(predict_gemm(g, jnp.asarray(x)))
    assert np.allclose(got, ref, atol=1e-5)


@pytest.mark.parametrize("pad", [16, 128])
def test_ensemble_gemm_padding_invariance(pad):
    x, y = _toy(300)
    rf = RandomForest(n_trees=4, max_depth=4).fit(x, y)
    ens = ensemble_to_gemm(rf.trees, pad_to=pad)
    got = np.asarray(predict_ensemble_gemm(ens, jnp.asarray(x)))
    ref = np.asarray(rf.predict_scores(jnp.asarray(x)))
    assert np.allclose(got, ref, atol=1e-5)


def test_gbt_regression_fits():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 4)).astype(np.float32)
    y = 2 * x[:, 0] - x[:, 1] + 0.1 * rng.normal(size=500)
    gbt = GradientBoostedTrees(n_trees=25, max_depth=3).fit(x, y)
    pred = np.asarray(gbt.predict(jnp.asarray(x)))
    assert np.corrcoef(pred, y)[0, 1] > 0.9


def test_l1_logistic_sparsity_monotone():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(600, 30)).astype(np.float32)
    y = (x[:, 0] - x[:, 1] > 0).astype(np.float32)
    s = []
    for l1 in (0.001, 0.05, 0.2):
        lr = LogisticRegression(l1=l1, steps=200).fit(x, y)
        s.append(lr.sparsity())
    assert s[0] <= s[1] <= s[2]
    assert s[2] > 0.5


def test_linear_regression_recovers_weights():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(800, 6)).astype(np.float32)
    w_true = np.asarray([2.0, -1.0, 0.0, 0.0, 0.5, 0.0], np.float32)
    y = x @ w_true + 3.0
    lr = LinearRegression(l1=0.01, steps=600, lr=0.2).fit(x, y)
    assert np.allclose(lr.weights, w_true, atol=0.15)
    assert abs(lr.bias - 3.0) < 0.2
    assert set(lr.zero_weight_features()) >= {2, 3}


@given(st.integers(0, 50))
def test_tree_pruning_sound_on_constrained_rows(seed):
    """Pruned tree must agree with the original on every row satisfying the
    constraint (the paper's soundness requirement for predicate pruning)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(300, 4)).astype(np.float32)
    y = (x[:, 0] + x[:, 2] > 0).astype(np.int32)
    tree = fit_tree_arrays(x, y, "classification", max_depth=5, min_leaf=5)
    lo, hi = sorted(rng.normal(size=2).tolist())
    pruned = tree.prune_with_constraints({0: (lo, hi)})
    mask = (x[:, 0] >= lo) & (x[:, 0] <= hi)
    if mask.sum() == 0:
        return
    assert np.allclose(pruned.predict_numpy(x[mask]),
                       tree.predict_numpy(x[mask]), atol=1e-6)
    assert pruned.n_nodes <= tree.n_nodes


def test_onehot_restrict():
    data = {"c": np.asarray([0, 1, 2, 1, 0])}
    enc = OneHotEncoder(["c"]).fit(data)
    full = np.asarray(enc.transform({"c": jnp.asarray(data["c"])}))
    sub = enc.restrict([1])     # keep category "1" only
    part = np.asarray(sub.transform({"c": jnp.asarray(data["c"])}))
    assert part.shape == (5, 1)
    assert np.allclose(part[:, 0], full[:, 1])


def test_mlp_restrict_features_consistent():
    x, y = _toy(300, d=6)
    mlp = MLP(hidden=(16,), n_outputs=2, steps=40).fit(x, y)
    keep = np.asarray([0, 1, 3])
    sub = mlp.restrict_features(keep)
    got = np.asarray(sub.predict_scores(jnp.asarray(x[:, keep])))
    # restriction zero-imputes dropped features
    x0 = x.copy()
    x0[:, [2, 4, 5]] = 0.0
    ref = np.asarray(mlp.predict_scores(jnp.asarray(x0)))
    assert np.allclose(got, ref, atol=1e-4)
