"""Distributed substrate tests: checkpointing, fault tolerance, elastic
resharding, gradient compression, data determinism, sharding rules."""

import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.distributed.compression import (compress_tree,
                                           dequantize_int8,
                                           make_error_feedback_compressor,
                                           quantize_int8)
from repro.distributed.elastic import plan_rescale
from repro.distributed.fault_tolerance import (FailureInjector,
                                               RestartableRunner)
from repro.distributed.sharding import (logical_to_pspec, serve_rules,
                                        train_rules)
from repro.data.lm_data import TokenStream
from repro.train.checkpoint import (latest_step, list_checkpoints,
                                    restore_checkpoint, save_checkpoint)
from repro.train.optimizer import AdamWConfig, cosine_schedule, wsd_schedule


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4)), "step": jnp.asarray(7)}}
    save_checkpoint(str(tmp_path), 5, tree, extra={"note": "x"})
    got, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 5 and extra == {"note": "x"}
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep_last=2)
    assert list_checkpoints(str(tmp_path)) == [4, 5]


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"w": jnp.zeros((5,))})


def test_restart_exactly_once(tmp_path):
    """After an injected failure the runner resumes from the checkpoint and
    the final state equals an uninterrupted run (determinism)."""

    def init():
        return {"x": jnp.asarray(0.0), "hist": jnp.zeros((30,))}

    def step(state, i):
        return {"x": state["x"] + i,
                "hist": state["hist"].at[i].set(i)}, {"i": i}

    r1 = RestartableRunner(str(tmp_path / "a"), ckpt_every=5)
    s_inj = RestartableRunner(str(tmp_path / "b"), ckpt_every=5)

    out_clean = {}
    def run(runner, injector, key):
        final = {}
        def stepper(state, i):
            s2, m = step(state, i)
            final["state"] = s2
            return s2, m
        stats = runner.run(init, stepper, 23, injector=injector)
        return final["state"], stats

    clean, stats_a = run(r1, None, "a")
    inj = FailureInjector(fail_at=13)
    crashy, stats_b = run(s_inj, inj, "b")
    assert inj.failures_seen == 1
    assert stats_b["restarts"] == 1
    np.testing.assert_allclose(np.asarray(clean["x"]),
                               np.asarray(crashy["x"]))
    np.testing.assert_allclose(np.asarray(clean["hist"]),
                               np.asarray(crashy["hist"]))


def test_quantize_int8_bounds_error():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)) * 3)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x)).max()
    assert err <= float(s) * 0.5 + 1e-6


def test_error_feedback_converges():
    """With error feedback, the *accumulated* compressed gradient tracks the
    accumulated true gradient (residual stays bounded)."""
    comp = make_error_feedback_compressor()
    rng = np.random.default_rng(1)
    total_true = np.zeros(50)
    total_sent = np.zeros(50)
    residual = None
    for _ in range(30):
        g = {"w": jnp.asarray(rng.normal(size=50) * 0.1)}
        sent, residual = comp(g, residual)
        total_true += np.asarray(g["w"])
        total_sent += np.asarray(sent["w"])
    drift = np.abs(total_true - total_sent).max()
    res = np.abs(np.asarray(residual["w"])).max()
    assert drift <= res + 1e-5    # drift equals the current residual


def test_compress_tree_small_relative_error():
    g = {"a": jnp.asarray(np.random.default_rng(2).normal(size=(64, 64)))}
    out = compress_tree(g)
    rel = np.abs(np.asarray(out["a"] - g["a"])).max() \
        / np.abs(np.asarray(g["a"])).max()
    assert rel < 0.01


def test_token_stream_deterministic_and_seekable():
    s1 = TokenStream(1000, 32, 4, seed=9)
    s2 = TokenStream(1000, 32, 4, seed=9)
    np.testing.assert_array_equal(s1.batch(17)["tokens"],
                                  s2.batch(17)["tokens"])
    assert not np.array_equal(s1.batch(17)["tokens"],
                              s1.batch(18)["tokens"])


def test_wsd_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, schedule="wsd", warmup_steps=10,
                      total_steps=100, decay_fraction=0.2)
    lrs = [float(wsd_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 79, 90, 100)]
    assert lrs[1] < lrs[2]            # warmup rising
    assert lrs[2] == lrs[3] == 1.0    # stable plateau at peak
    assert lrs[4] == 1.0              # still stable just before decay
    assert lrs[5] < 1.0 and lrs[6] < lrs[5]   # decaying


def test_sharding_rules_mapping():
    import os
    # rules are pure data; no devices needed
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    rules = train_rules(FakeMesh())
    spec = logical_to_pspec(("embed", "mlp"), rules)
    assert spec == jax.sharding.PartitionSpec(("pod", "data"), "model")
    srules = serve_rules(FakeMesh())
    spec2 = logical_to_pspec(("embed", "heads"), srules)
    assert spec2 == jax.sharding.PartitionSpec(None, "model")


def test_plan_rescale_capacity():
    class M:
        class devices:
            size = 256
        shape = {"data": 16, "model": 16}
    state = {"w": jax.ShapeDtypeStruct((1 << 30,), jnp.float32)}  # 4 GB
    plan = plan_rescale(state, None, M())
    assert plan.new_devices == 256
    assert plan.fits
    assert plan.bytes_per_device == (4 << 30) // 256
