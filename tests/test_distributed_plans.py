"""Partition-wise sharded joins + two-phase aggregation.

Five layers:

1. **Key-aware partitioning units** — ``partition_by`` registration
   (boundary snapping on duplicate keys, explicit ``partition_bounds``
   incl. empty partitions, sortedness enforcement) and the zone-map-based
   ``compatible_partitioning`` check (aligned / misaligned / NaN /
   unkeyed cases).
2. **Rule marking units** — ``distributed_plan`` marks co-partitioned
   joins ``partition_wise`` and eligible aggregations ``two_phase``;
   ineligible shapes (non-co-partitioned sides, scans above the
   aggregation, multiple aggregations) stay unmarked.
3. **Partial/combine units** — ``partial_aggregate`` states over row
   pieces fold (``combine_partials``) to exactly ``group_aggregate`` over
   the whole table, keyed and global, including empty pieces and empty
   groups.
4. **Service integration** — ``ExecutionConfig(sharded=True)`` routes
   distributed-rewritten plans through aligned-morsel execution; results
   match unsharded execution; warm repeats compile nothing; override
   tables, all-pruned anchors and mid-flight re-registrations fall back.
5. **Bit-exactness property** (hypothesis + seeded twin): random
   partition counts/row counts/validity (integer-valued data, so float
   sums are exact) — sharded == unsharded bitwise; a non-co-partitioned
   pair must fall back and still agree.
"""

import numpy as np
import pytest

from repro.core import (CrossOptimizer, ExecutionConfig, ModelStore,
                        OptimizerConfig, execute)
from repro.core.ir import Plan
from repro.core.partition import PartitionedTable, compatible_partitioning
from repro.relational import ops as rel_ops
from repro.relational.expr import col
from repro.relational.table import ColumnSchema, Table
from repro.serve import PredictionService

pytestmark = pytest.mark.tier1

AGG_FNS = ["sum", "count", "avg", "min", "max"]


def _table(**cols):
    valid = cols.pop("valid", None)
    t = Table.from_pydict({k: np.asarray(v) for k, v in cols.items()})
    if valid is not None:
        t = t.with_valid(np.asarray(valid, bool))
    return t


def _co_store(n_pids=12, n_rows=60, bounds=(4, 8), seed=0,
              fact_valid=None, dim_valid=None):
    """Fact table ``visits`` + dim table ``patients``, both range-
    partitioned on ``pid`` with the same explicit bounds."""
    rng = np.random.RandomState(seed)
    pids = np.sort(rng.randint(0, n_pids, n_rows)).astype(np.int32)
    visits = _table(pid=pids,
                    amount=rng.randint(-4, 5, n_rows).astype(np.float32),
                    valid=fact_valid)
    patients = _table(pid=np.arange(n_pids, dtype=np.int32),
                      region=(np.arange(n_pids) % 3).astype(np.int32),
                      weight=rng.randint(0, 4, n_pids).astype(np.float32),
                      valid=dim_valid)
    store = ModelStore()
    store.register_table("visits", visits, partition_by="pid",
                         partition_bounds=list(bounds))
    store.register_table("patients", patients, partition_by="pid",
                         partition_bounds=list(bounds))
    return store, visits, patients


def _join_plan(filter_pred=None):
    plan = Plan()
    v = plan.emit("scan", "RA", [], "table", table="visits")
    if filter_pred is not None:
        v = plan.emit("filter", "RA", [v], "table", predicate=filter_pred)
    p = plan.emit("scan", "RA", [], "table", table="patients")
    plan.output = plan.emit("join", "RA", [v, p], "table", on="pid",
                            how="inner")
    return plan


def _join_agg_plan(aggs=None, key="region", num_groups=3,
                   filter_pred=None):
    plan = _join_plan(filter_pred)
    aggs = aggs if aggs is not None else {
        "total": ("sum", "amount"), "n": ("count", None),
        "avg_a": ("avg", "amount"), "lo": ("min", "amount"),
        "hi": ("max", "amount")}
    plan.output = plan.emit("group_agg", "RA", [plan.output], "table",
                            key=key, aggs=aggs, num_groups=num_groups)
    return plan


def _sharded(store, **knobs):
    knobs.setdefault("shard_min_bucket_rows", 4)
    knobs.setdefault("shard_morsel_rows", 16)
    return PredictionService(store, execution_config=ExecutionConfig(
        sharded=True, **knobs))


def _assert_tables_equal(got, want):
    assert got.capacity == want.capacity
    assert (np.asarray(got.valid) == np.asarray(want.valid)).all()
    assert set(got.columns) == set(want.columns)
    for k in want.columns:
        g, w = np.asarray(got.columns[k]), np.asarray(want.columns[k])
        assert (g == w).all(), k


def _assert_same_valid_rows(got, want):
    vg, vw = np.asarray(got.valid), np.asarray(want.valid)
    assert set(got.columns) == set(want.columns)
    for k in want.columns:
        g = np.asarray(got.columns[k])[vg]
        w = np.asarray(want.columns[k])[vw]
        assert g.shape == w.shape and (g == w).all(), k


# ---------------------------------------------------------------------------
# 1. Key-aware partitioning + compatible_partitioning
# ---------------------------------------------------------------------------

def test_partition_by_snaps_duplicate_keys_to_one_partition():
    t = _table(pid=np.asarray([0, 1, 1, 1, 2, 3], np.int32))
    pt = PartitionedTable.build(t, partition_rows=2, partition_by="pid")
    assert pt.partition_by == "pid"
    # the naive cut at row 2 would split the run of 1s; it must extend
    assert [(p.start, p.stop) for p in pt.partitions] == [(0, 4), (4, 6)]


def test_partition_by_requires_sorted_keys():
    t = _table(pid=np.asarray([3, 1, 2], np.int32))
    with pytest.raises(ValueError, match="not sorted"):
        PartitionedTable.build(t, partition_rows=2, partition_by="pid")
    with pytest.raises(ValueError, match="not sorted"):
        PartitionedTable.build_by_bounds(t, "pid", [2])


def test_partition_bounds_tile_with_empty_partitions():
    t = _table(pid=np.asarray([0, 0, 5, 5, 9], np.int32))
    pt = PartitionedTable.build_by_bounds(t, "pid", [2, 4, 7])
    assert pt.n_partitions == 4
    assert [(p.start, p.stop) for p in pt.partitions] == \
        [(0, 2), (2, 2), (2, 4), (4, 5)]        # [2,4) holds no rows
    assert pt.partitions[1].zone.n_valid == 0


def test_register_table_partition_by_validation():
    store = ModelStore()
    t = _table(pid=np.arange(6, dtype=np.int32))
    with pytest.raises(ValueError, match="partition_by requires"):
        store.register_table("t", t, partition_by="pid")
    with pytest.raises(ValueError, match="requires partition_by"):
        store.register_table("t", t, partition_bounds=[2])
    store.register_table("t", t, partition_by="pid", partition_rows=2)
    assert store.get_partitioned("t").partition_by == "pid"


def test_compatible_partitioning_aligned_and_misaligned():
    store, *_ = _co_store(bounds=(4, 8))
    a = store.get_partitioned("visits")
    b = store.get_partitioned("patients")
    assert compatible_partitioning(a, b, "pid")
    assert not compatible_partitioning(a, b, "amount")   # wrong key
    assert not compatible_partitioning(a, None, "pid")
    # different bounds -> overlapping ranges across indices
    store2, *_ = _co_store(bounds=(6,))
    assert not compatible_partitioning(
        a, store2.get_partitioned("patients"), "pid")
    # row-count partitioning has no declared key
    t = _table(pid=np.arange(8, dtype=np.int32))
    unkeyed = PartitionedTable.build(t, partition_rows=4)
    assert not compatible_partitioning(a, unkeyed, "pid")


def test_compatible_partitioning_conservative_on_nan_keys():
    vals = np.asarray([0.0, np.nan, 5.0, 9.0], np.float32)
    t = _table(pid=vals)
    # NaN sorts "anywhere" for the sortedness check but poisons the zone
    # stats of its partition -> the check must refuse to prove anything
    pt = PartitionedTable.build_by_bounds(t, "pid", [4.0])
    other = PartitionedTable.build_by_bounds(
        _table(pid=np.asarray([1.0, 6.0], np.float32)), "pid", [4.0])
    assert not compatible_partitioning(pt, other, "pid")
    assert compatible_partitioning(other, other, "pid")


def test_compatible_partitioning_ignores_invalid_rows():
    # an all-invalid partition has no key range: it constrains nothing
    t1 = _table(pid=np.asarray([0, 1, 8, 9], np.int32),
                valid=[1, 1, 0, 0])
    t2 = _table(pid=np.asarray([1, 7], np.int32))
    a = PartitionedTable.build_by_bounds(t1, "pid", [5])
    b = PartitionedTable.build_by_bounds(t2, "pid", [5])
    # t1's second partition is all-invalid; its physical keys (8, 9) are
    # never joined, so alignment only needs the valid ranges
    assert compatible_partitioning(a, b, "pid")


# ---------------------------------------------------------------------------
# 2. Rule marking
# ---------------------------------------------------------------------------

def _optimize(store, plan, **cfg):
    return CrossOptimizer(store, OptimizerConfig(**cfg)).optimize(plan)


def test_rule_marks_co_partitioned_join_and_two_phase_agg():
    store, *_ = _co_store()
    opt, report = _optimize(store, _join_agg_plan())
    assert report.fired("distributed_plan")
    join = opt.find("join")[0]
    agg = opt.find("group_agg")[0]
    assert join.attrs.get("partition_wise") is True
    assert agg.attrs.get("two_phase") is True
    # marks are part of the structural signature: a distributed-rewritten
    # plan must never share an executable with its whole-table twin
    from repro.core.ir import plan_signature
    opt2, _ = _optimize(store, _join_agg_plan(),
                        enable_distributed_plan=False)
    assert "partition_wise" not in opt2.find("join")[0].attrs
    assert plan_signature(opt) != plan_signature(opt2)


def test_rule_marks_non_co_partitioned_join_as_exchange():
    store, visits, patients = _co_store()
    # re-register the dim side with different bounds: no longer aligned —
    # the join cannot go partition-wise, but a hash-repartition exchange
    # restores locality, so the rule marks it `exchange` and the agg above
    # it stays two-phase eligible (per-bucket partials fold the same way)
    store.register_table("patients", patients, partition_by="pid",
                         partition_bounds=[6])
    opt, report = _optimize(store, _join_agg_plan())
    join = opt.find("join")[0]
    assert "partition_wise" not in join.attrs
    assert join.attrs.get("exchange") is True
    assert opt.find("group_agg")[0].attrs.get("two_phase") is True
    assert report.fired("distributed_plan")
    # the exchange knob turns the mark off wholesale
    opt2, _ = _optimize(store, _join_agg_plan(), enable_exchange=False)
    assert "exchange" not in opt2.find("join")[0].attrs
    assert "partition_wise" not in opt2.find("join")[0].attrs


def test_rule_requires_intact_join_key_provenance():
    """A rename/map/attach_column between the scan and the join can bind
    *different values* under the partition key's name; the zone maps say
    nothing about those, so the join must not be marked partition-wise
    (regression: this used to silently drop cross-partition matches)."""
    store, visits, patients = _co_store(n_pids=12, n_rows=60,
                                        bounds=(4, 8))
    # visits gains an `other` column whose values are NOT pid-aligned
    rng = np.random.RandomState(2)
    shuffled = Table(dict(visits.columns,
                          other=np.asarray(rng.randint(0, 12, 60),
                                           np.int32)),
                     visits.valid,
                     visits.schema.with_column(
                         ColumnSchema("other", np.int32)))
    store.register_table("visits", shuffled, partition_by="pid",
                         partition_bounds=[4, 8])

    def rebound_plan():
        plan = Plan()
        v = plan.emit("scan", "RA", [], "table", table="visits")
        pr = plan.emit("project", "RA", [v], "table",
                       columns=["other", "amount"])
        rn = plan.emit("rename", "RA", [pr], "table",
                       mapping={"other": "pid"})
        p = plan.emit("scan", "RA", [], "table", table="patients")
        plan.output = plan.emit("join", "RA", [rn, p], "table", on="pid",
                                how="inner")
        return plan

    opt, _ = _optimize(store, rebound_plan())
    assert "partition_wise" not in opt.find("join")[0].attrs
    # end-to-end: the sharded service must fall back and still agree
    base = PredictionService(store)
    svc = _sharded(store)
    want = base.run(rebound_plan())
    got = svc.run(rebound_plan())
    assert (np.asarray(got.valid) == np.asarray(want.valid)).all()
    _assert_same_valid_rows(got, want)
    assert svc.stats.sharded_executions == 0
    base.close(); svc.close()
    # a genuinely intact key still qualifies (filter/project keep values)
    plan = _join_plan(filter_pred=col("amount") > 0)
    opt2, _ = _optimize(store, plan)
    assert opt2.find("join")[0].attrs.get("partition_wise") is True


def test_rule_two_phase_over_single_partitioned_scan():
    """Two-phase aggregation needs no join (and no partition key): any
    partitioned scan subtree qualifies."""
    store = ModelStore()
    t = _table(g=np.asarray([0, 1, 0, 1, 2, 0], np.int32),
               x=np.arange(6).astype(np.float32))
    store.register_table("t", t, partition_rows=2)
    plan = Plan()
    s = plan.emit("scan", "RA", [], "table", table="t")
    plan.output = plan.emit("group_agg", "RA", [s], "table", key="g",
                            aggs={"sx": ("sum", "x")}, num_groups=3)
    opt, _ = _optimize(store, plan)
    assert opt.find("group_agg")[0].attrs.get("two_phase") is True


def test_rule_skips_agg_with_scan_above_or_second_agg():
    store, *_ = _co_store()
    plan = _join_agg_plan(aggs={"total": ("sum", "amount")})
    # a scan joins the aggregate output downstream: global stage would
    # need plan inputs of its own -> ineligible
    extra = plan.emit("scan", "RA", [], "table", table="patients")
    plan.output = plan.emit("union", "RA", [plan.output, extra], "table")
    opt, _ = _optimize(store, plan)
    assert "two_phase" not in opt.find("group_agg")[0].attrs
    # two aggregations: neither is "the" split point
    plan2 = _join_agg_plan(aggs={"total": ("sum", "amount")})
    plan2.output = plan2.emit("group_agg", "RA", [plan2.output], "table",
                              key=None, aggs={"m": ("max", "total")})
    opt2, _ = _optimize(store, plan2)
    assert all("two_phase" not in n.attrs
               for n in opt2.find("group_agg"))


# ---------------------------------------------------------------------------
# 3. Partial / combine aggregation units
# ---------------------------------------------------------------------------

def _pieces(table, cuts):
    edges = [0] + list(cuts) + [table.capacity]
    return [Table({k: v[edges[i]:edges[i + 1]]
                   for k, v in table.columns.items()},
                  table.valid[edges[i]:edges[i + 1]], table.schema)
            for i in range(len(edges) - 1)]


@pytest.mark.parametrize("key,num_groups", [("g", 4), (None, None)])
def test_partial_combine_equals_one_shot(key, num_groups):
    rng = np.random.RandomState(3)
    t = _table(g=rng.randint(0, 4, 20).astype(np.int32),
               x=rng.randint(-5, 6, 20).astype(np.float32),
               valid=rng.rand(20) < 0.7)
    aggs = {f"{fn}_x": (fn, "x") for fn in AGG_FNS}
    aggs["rows"] = ("count", None)
    want = rel_ops.group_aggregate(t, key, aggs, num_groups)
    for cuts in ([7], [0, 20], [5, 5, 13]):      # incl. empty pieces
        partials = [rel_ops.partial_aggregate(p, key, aggs, num_groups)
                    for p in _pieces(t, cuts)]
        got = rel_ops.combine_partials(partials, key, aggs)
        _assert_tables_equal(got, want)
        for k in want.columns:
            assert got.columns[k].dtype == want.columns[k].dtype, k


def test_partial_combine_empty_groups_and_all_invalid():
    t = _table(g=np.asarray([0, 0, 3], np.int32),
               x=np.asarray([1.0, 2.0, 7.0], np.float32),
               valid=[1, 1, 0])
    aggs = {"lo": ("min", "x"), "hi": ("max", "x"), "n": ("count", None)}
    want = rel_ops.group_aggregate(t, "g", aggs, 4)
    partials = [rel_ops.partial_aggregate(p, "g", aggs, 4)
                for p in _pieces(t, [1])]
    got = rel_ops.combine_partials(partials, "g", aggs)
    _assert_tables_equal(got, want)         # groups 1, 2, 3 invalid
    assert not np.asarray(got.valid)[3]     # only-invalid-rows group
    # fully invalid input: every group empty, same as one-shot
    t0 = t.with_valid(np.zeros(3, bool))
    want0 = rel_ops.group_aggregate(t0, "g", aggs, 4)
    got0 = rel_ops.combine_partials(
        [rel_ops.partial_aggregate(t0, "g", aggs, 4)], "g", aggs)
    _assert_tables_equal(got0, want0)


def test_partial_aggregate_rejects_non_combinable():
    t = _table(g=np.zeros(3, np.int32), x=np.arange(3.0))
    with pytest.raises(ValueError, match="no mergeable partial state"):
        rel_ops.partial_aggregate(t, "g", {"w": ("median", "x")}, 2)


# ---------------------------------------------------------------------------
# 4. Service integration
# ---------------------------------------------------------------------------

def test_service_join_agg_bit_exact_vs_unsharded():
    store, *_ = _co_store(n_pids=12, n_rows=80, bounds=(3, 6, 9))
    base = PredictionService(store)
    svc = _sharded(store)
    plan = _join_agg_plan()
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    _assert_tables_equal(got, want)
    info = svc.shard_info()
    assert info["sharded_executions"] == 1
    assert info["join_executions"] == 1
    assert info["agg_combines"] == 1
    assert info["partial_aggs"] >= 1
    base.close(); svc.close()


def test_service_join_only_valid_rows_exact():
    store, *_ = _co_store(n_pids=10, n_rows=50, bounds=(2, 5, 7),
                          dim_valid=[i % 4 != 1 for i in range(10)])
    base = PredictionService(store)
    svc = _sharded(store)
    plan = _join_plan()
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    # inner join: unmatched left rows carry garbage-but-masked right
    # columns, so equality is on the mask and the valid rows
    assert (np.asarray(got.valid) == np.asarray(want.valid)).all()
    _assert_same_valid_rows(got, want)
    assert svc.shard_info()["join_executions"] == 1
    assert svc.shard_info()["agg_combines"] == 0
    base.close(); svc.close()


def test_service_global_agg_over_partitioned_scan_via_sql():
    """SQL-level global aggregate over one partitioned table rides the
    two-phase path (no join, no partition key needed)."""
    store = ModelStore()
    rng = np.random.RandomState(5)
    t = _table(x=rng.randint(0, 9, 40).astype(np.float32),
               valid=rng.rand(40) < 0.8)
    store.register_table("t", t, partition_rows=8)
    sql = "SELECT SUM(x) AS s, COUNT(x) AS n, MAX(x) AS m FROM t"
    base = PredictionService(store)
    svc = _sharded(store)
    want, got = base.run(sql), svc.run(sql)
    _assert_tables_equal(got, want)
    assert svc.shard_info()["agg_combines"] == 1
    base.close(); svc.close()


def test_service_warm_repeats_compile_nothing():
    store, *_ = _co_store()
    svc = _sharded(store)
    plan = _join_agg_plan()
    svc.run(plan.copy())
    before = (svc.stats.cache_misses, svc.stats.shard_compiles,
              svc.stats.jit_traces)
    for _ in range(3):
        svc.run(plan.copy())
    after = (svc.stats.cache_misses, svc.stats.shard_compiles,
             svc.stats.jit_traces)
    assert before == after
    assert svc.stats.shard_hits >= 3
    svc.close()


def test_service_pruned_anchor_and_all_pruned():
    store, *_ = _co_store(n_pids=12, n_rows=60, bounds=(4, 8))
    base = PredictionService(store)
    svc = _sharded(store)
    plan = _join_agg_plan(filter_pred=col("pid") < 4)
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    _assert_tables_equal(got, want)
    assert svc.stats.partitions_pruned >= 1      # zone maps skipped some
    # every anchor partition pruned: combine folds the identity partial
    plan0 = _join_agg_plan(filter_pred=col("pid") < 0)
    want0 = base.run(plan0.copy())
    got0 = svc.run(plan0.copy())
    _assert_tables_equal(got0, want0)
    assert not np.asarray(got0.valid).any()
    base.close(); svc.close()


def test_service_override_tables_never_distribute():
    store, visits, _ = _co_store()
    base = PredictionService(store)
    svc = _sharded(store)
    sub = Table({k: v[:10] for k, v in visits.columns.items()},
                visits.valid[:10], visits.schema)
    plan = _join_agg_plan()
    want = base.run(plan.copy(), {"visits": sub})
    got = svc.run(plan.copy(), {"visits": sub})
    _assert_tables_equal(got, want)
    assert svc.stats.sharded_executions == 0
    compiled = svc.compile(plan.copy(), {"visits": sub})
    assert compiled.dist is None
    assert "partition_wise" not in compiled.plan.find("join")[0].attrs
    base.close(); svc.close()


def test_service_reregistration_falls_back_to_whole_table():
    """A mid-flight re-registration (racing the invalidation hook) voids
    the co-partitioning proof: the held executable must serve whole-table
    instead of joining misaligned partition pairs."""
    store, visits, patients = _co_store()
    svc = _sharded(store)
    plan = _join_agg_plan()
    compiled = svc.compile(plan.copy())
    assert compiled.dist is not None
    want = execute(compiled.plan, store, jit=False)
    # different bounds, same partition count: stale alignment is wrong
    store.register_table("patients", patients, partition_by="pid",
                         partition_bounds=[5, 9])
    tabs = {"visits": store.get_table("visits"),
            "patients": store.get_table("patients")}
    out = svc._execute_sharded(compiled, tabs)
    _assert_tables_equal(out, want)
    assert svc.stats.sharded_executions == 0     # whole-table fallback
    svc.close()


def test_service_multi_morsel_waves_match_single_morsel():
    """Tiny morsel cap -> several waves per device; results identical to
    the single-morsel placement (combine order is partition order, not
    placement order)."""
    store, *_ = _co_store(n_pids=16, n_rows=100, bounds=(2, 5, 7, 9, 12))
    plan = _join_agg_plan(num_groups=3)
    svc_big = _sharded(store, shard_morsel_rows=1 << 16)
    svc_small = _sharded(store, shard_morsel_rows=8)
    a = svc_big.run(plan.copy())
    b = svc_small.run(plan.copy())
    _assert_tables_equal(a, b)
    assert svc_small.shard_info()["partial_aggs"] \
        > svc_big.shard_info()["partial_aggs"]
    svc_big.close(); svc_small.close()


def test_service_join_with_model_valid_rows_exact():
    """The paper's shape: FK join feeding featurize -> predict, sharded
    partition-wise — predictions per valid row identical to unsharded."""
    from repro.ml import (LogisticRegression, Pipeline, PipelineMetadata,
                          StandardScaler)
    store, visits, patients = _co_store(n_pids=12, n_rows=80,
                                        bounds=(4, 8))
    data = {"amount": np.asarray(visits.column("amount"), np.float32),
            "weight": np.random.RandomState(0).rand(80).astype(np.float32)}
    sc = StandardScaler(["amount", "weight"]).fit(data)
    pipe = Pipeline([sc], LogisticRegression(steps=10),
                    PipelineMetadata(name="m", task="classification"))
    pipe.fit(data, (data["amount"] > 0).astype(np.int32))
    store.register_model("m", pipe)
    plan = _join_plan()
    f = plan.emit("featurize", "MLD", [plan.output], "matrix",
                  pipeline_name="m", featurizers=pipe.featurizers,
                  input_columns=pipe.input_columns())
    m = plan.emit("predict_model", "MLD", [f], "matrix", model=pipe.model,
                  model_name="m", proba=True, task="classification")
    plan.output = plan.emit("attach_column", "RA", [plan.output, m],
                            "table", name="p")
    base = PredictionService(store)
    svc = _sharded(store)
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    assert (np.asarray(got.valid) == np.asarray(want.valid)).all()
    _assert_same_valid_rows(got, want)
    assert svc.shard_info()["join_executions"] == 1
    base.close(); svc.close()


# ---------------------------------------------------------------------------
# 5. Bit-exactness property: sharded == unsharded over random shapes
# ---------------------------------------------------------------------------

def _check_distributed_bit_exact(n_pids, fact_pids, fact_vals, fact_valid,
                                 dim_valid, bounds, co_partitioned,
                                 agg_fns):
    fact_pids = np.sort(np.asarray(fact_pids, np.int32))
    visits = _table(pid=fact_pids,
                    amount=np.asarray(fact_vals, np.float32),
                    valid=fact_valid)
    patients = _table(pid=np.arange(n_pids, dtype=np.int32),
                      region=(np.arange(n_pids) % 3).astype(np.int32),
                      valid=dim_valid)
    store = ModelStore()
    store.register_table("visits", visits, partition_by="pid",
                         partition_bounds=list(bounds))
    dim_bounds = list(bounds) if co_partitioned \
        else [b + 1 for b in bounds] + [max(bounds) + 2]
    store.register_table("patients", patients, partition_by="pid",
                         partition_bounds=dim_bounds)
    aggs = {f"{fn}_{i}": (fn, "amount") for i, fn in enumerate(agg_fns)}
    plan = _join_agg_plan(aggs=aggs, key="region", num_groups=3)
    base = PredictionService(store, jit=False)
    svc = _sharded(store, shard_morsel_rows=8)
    try:
        want = base.run(plan.copy())
        got = svc.run(plan.copy())
        _assert_tables_equal(got, want)
        if not co_partitioned:
            assert svc.stats.sharded_executions == 0
    finally:
        base.close(); svc.close()


def test_distributed_randomized_sweep():
    """Seeded twin of the hypothesis property below (runs everywhere,
    mirrors the repo convention — change both together)."""
    rng = np.random.RandomState(11)
    for i in range(25):
        n_pids = int(rng.randint(1, 13))
        n_rows = int(rng.randint(1, 40))
        n_bounds = int(rng.randint(1, 5))
        bounds = sorted(int(b) for b in rng.randint(0, n_pids + 1,
                                                    n_bounds))
        _check_distributed_bit_exact(
            n_pids=n_pids,
            fact_pids=rng.randint(0, n_pids, n_rows),
            fact_vals=rng.randint(-4, 5, n_rows),
            fact_valid=rng.rand(n_rows) < rng.choice([0.0, 0.6, 1.0]),
            dim_valid=rng.rand(n_pids) < 0.9,
            bounds=bounds,
            co_partitioned=bool(i % 5),          # every 5th must fall back
            agg_fns=[AGG_FNS[rng.randint(len(AGG_FNS))]
                     for _ in range(rng.randint(1, 4))])


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @given(
        n_pids=st.integers(min_value=1, max_value=12),
        fact=st.lists(st.tuples(st.integers(0, 11),     # pid (clamped)
                                st.integers(-4, 4),     # amount
                                st.booleans()),         # valid
                      min_size=1, max_size=32),
        dim_valid_bits=st.lists(st.booleans(), min_size=12, max_size=12),
        bounds=st.lists(st.integers(0, 12), min_size=1, max_size=4),
        co_partitioned=st.booleans(),
        agg_fns=st.lists(st.sampled_from(AGG_FNS), min_size=1,
                         max_size=3),
    )
    @settings(max_examples=30, deadline=None)
    def test_distributed_bit_exact_property(n_pids, fact, dim_valid_bits,
                                            bounds, co_partitioned,
                                            agg_fns):
        """Partition-wise join + two-phase aggregation == unsharded
        execution, bitwise, across random partition layouts (empty
        partitions included — bounds may repeat or fall outside the key
        range) and row counts; the non-co-partitioned draw must fall back
        to whole-table execution and still agree."""
        _check_distributed_bit_exact(
            n_pids=n_pids,
            fact_pids=[min(p, n_pids - 1) for p, _v, _m in fact],
            fact_vals=[v for _p, v, _m in fact],
            fact_valid=[m for _p, _v, m in fact],
            dim_valid=dim_valid_bits[:n_pids],
            bounds=sorted(bounds),
            co_partitioned=co_partitioned,
            agg_fns=agg_fns)
