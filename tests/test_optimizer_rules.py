"""The central system invariant (paper §4): every cross-optimization is a
*semantics-preserving* plan rewrite.  For each rule (and all rules combined)
we execute optimized and unoptimized plans and require identical results.
"""

import numpy as np
import pytest

from repro.core import (CrossOptimizer, OptimizerConfig, execute,
                        parse_query)

QUERIES = [
    ("pregnant filter + model in select",
     "SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
     "JOIN blood_tests ON pid WHERE pregnant = 1"),
    ("model in predicate",
     "SELECT pid FROM patient_info JOIN blood_tests ON pid "
     "WHERE PREDICT(MODEL='los') > 6 AND age > 40"),
    ("three-way join, unused table",
     "SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
     "JOIN blood_tests ON pid JOIN prenatal_tests ON pid "
     "WHERE rcount > 1"),
    ("aggregate over predictions",
     "SELECT AVG(p) AS avg_p FROM (x) ",   # placeholder replaced below
     ),
]


def _same(a, b, tol=1e-4):
    assert set(a) == set(b)
    for k in a:
        va, vb = a[k], b[k]
        assert len(va) == len(vb), (k, len(va), len(vb))
        if va and isinstance(va[0], float):
            assert np.allclose(va, vb, atol=tol), k
        else:
            assert va == vb, k


CONFIGS = {
    "all_rules": OptimizerConfig(),
    "pruning_only": OptimizerConfig(
        enable_projection_pushdown=False, enable_join_elimination=False,
        enable_model_inlining=False, enable_nn_translation=False),
    "pushdown_only": OptimizerConfig(
        enable_model_pruning=False, enable_model_inlining=False,
        enable_nn_translation=False),
    "inlining": OptimizerConfig(inline_max_nodes=100_000,
                                enable_nn_translation=False),
    "nn_translation": OptimizerConfig(enable_model_inlining=False,
                                      nn_translate_single_trees="always",
                                      gemm_pad_to=16),
    "splitting": OptimizerConfig(enable_model_query_splitting=True,
                                 split_imbalance=0.95,
                                 enable_model_inlining=False,
                                 enable_nn_translation=False),
}


@pytest.mark.parametrize("cfg_name", sorted(CONFIGS))
@pytest.mark.parametrize("query", [q for _, q in QUERIES[:3]],
                         ids=[n for n, _ in QUERIES[:3]])
def test_rule_preserves_semantics(hospital_tree, cfg_name, query):
    store, data, pipe = hospital_tree
    plan = parse_query(query, store)
    oplan, report = CrossOptimizer(store, CONFIGS[cfg_name]).optimize(plan)
    a = execute(plan, store).to_pydict()
    b = execute(oplan, store).to_pydict()
    if cfg_name == "splitting":
        # union reorders rows: compare as sorted sets
        order_a = np.argsort(a["pid"])
        order_b = np.argsort(b["pid"])
        for k in a:
            va = np.asarray(a[k])[order_a]
            vb = np.asarray(b[k])[order_b]
            assert np.allclose(va, vb, atol=1e-4), k
    else:
        _same(a, b)


def test_one_hot_pruning_lr(flights):
    store, fcols, fy, pipe = flights
    sql = ("SELECT origin, PREDICT_PROBA(MODEL='delay') AS p FROM flights "
           "WHERE dest = 3")
    plan = parse_query(sql, store)
    oplan, report = CrossOptimizer(store, OptimizerConfig()).optimize(plan)
    assert report.fired("predicate_model_pruning")
    a = execute(plan, store).to_pydict()
    b = execute(oplan, store).to_pydict()
    _same(a, b, tol=1e-3)


def test_join_elimination_fires(hospital_tree):
    store, data, pipe = hospital_tree
    sql = ("SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
           "JOIN blood_tests ON pid JOIN prenatal_tests ON pid")
    plan = parse_query(sql, store)
    oplan, report = CrossOptimizer(store, OptimizerConfig()).optimize(plan)
    assert report.fired("join_elimination")
    joins = [n for n in oplan.nodes.values() if n.op == "join"]
    assert len(joins) == 1      # prenatal join dropped, blood join kept
    _same(execute(plan, store).to_pydict(),
          execute(oplan, store).to_pydict())


def test_pruning_shrinks_model(hospital_tree):
    store, data, pipe = hospital_tree
    sql = ("SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
           "JOIN blood_tests ON pid WHERE pregnant = 1 AND age > 35")
    plan = parse_query(sql, store)
    cfg = OptimizerConfig(enable_model_inlining=False,
                          enable_nn_translation=False)
    oplan, report = CrossOptimizer(store, cfg).optimize(plan)
    pred = next(n for n in oplan.nodes.values() if n.op == "predict_model")
    assert pred.attrs["model"].tree.n_nodes < pipe.model.tree.n_nodes


def test_stats_derived_pruning(hospital_tree):
    """Data-property pruning (§4.1): even with no WHERE clause, registered
    table stats bound each column, pruning splits outside the data range."""
    store, data, pipe = hospital_tree
    sql = ("SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
           "JOIN blood_tests ON pid")
    plan = parse_query(sql, store)
    cfg = OptimizerConfig(enable_model_inlining=False,
                          enable_nn_translation=False)
    oplan, report = CrossOptimizer(store, cfg).optimize(plan)
    _same(execute(plan, store).to_pydict(),
          execute(oplan, store).to_pydict())


def test_constant_folding_removes_true_filter(hospital_tree):
    store, _, _ = hospital_tree
    sql = "SELECT pid FROM patient_info WHERE 1 = 1 AND age > 200"
    plan = parse_query(sql, store)
    oplan, report = CrossOptimizer(store, OptimizerConfig()).optimize(plan)
    assert report.fired("constant_folding")
    out = execute(oplan, store)
    assert int(out.num_valid()) == 0


def test_external_runtime_selection(hospital_tree):
    store, data, pipe = hospital_tree
    import copy
    ext = copy.copy(pipe)
    ext.metadata = copy.copy(pipe.metadata)
    ext.metadata.flavor = "external"
    store.register_model("los_ext", ext)
    sql = ("SELECT pid, PREDICT(MODEL='los_ext') AS los "
           "FROM patient_info JOIN blood_tests ON pid LIMIT 50")
    plan = parse_query(sql, store)
    oplan, report = CrossOptimizer(store, OptimizerConfig()).optimize(plan)
    pred = next(n for n in oplan.nodes.values() if n.op == "predict_model")
    assert pred.runtime == "external"
    a = execute(plan, store).to_pydict()
    b = execute(oplan, store).to_pydict()
    _same(a, b)
