"""Model store, clustering, codegen runtimes, HLO analyzer units."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (CrossOptimizer, ModelStore, OptimizerConfig,
                        execute, parse_query)
from repro.core.clustering import build_clustered_model, kmeans
from repro.core.codegen import ExecutionConfig, compile_plan
from repro.launch.hlo_analysis import analyze_hlo


# -- model store --------------------------------------------------------------

def test_model_store_versioning(hospital_tree):
    store, _, pipe = hospital_tree
    s = ModelStore()
    v1 = s.register_model("m", pipe)
    v2 = s.register_model("m", pipe)
    assert (v1, v2) == (1, 2)
    assert s.get_model("m", version=1) is pipe
    assert s.model_version("m") == 2


def test_model_store_transaction_rollback(hospital_tree):
    _, _, pipe = hospital_tree
    s = ModelStore()
    with pytest.raises(RuntimeError):
        with s.transaction() as txn:
            txn.register("m", pipe)
            raise RuntimeError("boom")
    assert s.model_version("m") == 0            # nothing committed
    actions = [r.action for r in s.audit_log]
    assert "rollback" in actions


def test_model_store_audit_reads(hospital_tree):
    _, _, pipe = hospital_tree
    s = ModelStore()
    s.register_model("m", pipe)
    s.get_model("m")
    actions = [r.action for r in s.audit_log]
    assert actions == ["register", "read"]


# -- clustering ---------------------------------------------------------------

def test_kmeans_separates_blobs():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(50, 2)) + 10
    b = rng.normal(size=(50, 2)) - 10
    x = jnp.asarray(np.vstack([a, b]), jnp.float32)
    cents, assign = kmeans(x, 2, seed=1)
    assign = np.asarray(assign)
    assert len(set(assign[:50])) == 1
    assert len(set(assign[50:])) == 1
    assert assign[0] != assign[-1]


def test_clustered_model_exact_routing(flights):
    store, fcols, fy, pipe = flights
    cm = build_clustered_model(pipe, {k: v[:1500] for k, v in fcols.items()},
                               k=4, cluster_columns=["origin", "dest",
                                                     "carrier"])
    cols = {k: jnp.asarray(v) for k, v in fcols.items()}
    full = np.asarray(pipe.predict(cols))
    routed = cm.predict_routed(cols)
    assert (full == routed).mean() > 0.999
    cost = cm.model_cost()
    assert cost["mean_cluster_features"] <= cost["original_features"]


# -- execution runtimes ----------------------------------------------------------

def test_external_and_container_runtimes_match_native(hospital_tree):
    store, _, _ = hospital_tree
    sql = ("SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
           "JOIN blood_tests ON pid WHERE age > 60")
    plan = parse_query(sql, store)
    native = execute(plan, store).to_pydict()
    for rt in ("external", "container"):
        p2 = plan.copy()
        for n in p2.nodes.values():
            if n.op == "predict_model":
                n.runtime = rt
        got = execute(p2, store,
                      config=ExecutionConfig(container_latency_s=0.0)
                      ).to_pydict()
        assert got["pid"] == native["pid"]
        assert np.allclose(got["los"], native["los"], atol=1e-4)


def test_unjitted_matches_jitted(hospital_tree):
    store, _, _ = hospital_tree
    sql = "SELECT pid, age FROM patient_info WHERE age > 70 LIMIT 10"
    plan = parse_query(sql, store)
    a = execute(plan, store, jit=True).to_pydict()
    b = execute(plan, store, jit=False).to_pydict()
    assert a == b


# -- HLO analyzer ----------------------------------------------------------------

def test_hlo_analyzer_loop_scaling():
    """Analytic check on a hand-built scan: trip-count-aware flop total."""
    import os
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)

    def f(x, w):
        def body(h, wl):
            return h @ wl, None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    comp = jax.jit(f).lower(x, w).compile()
    cost = analyze_hlo(comp.as_text())
    expected = 5 * 2 * 32 * 64 * 64
    assert abs(cost.flops - expected) / expected < 0.05
    assert cost.total_collective_bytes == 0


def test_hlo_analyzer_collectives_counted():
    txt = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main.1 (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%p0), replica_groups={}
  ROOT %copy.1 = f32[128,256]{1,0} copy(%all-reduce.1)
}
"""
    cost = analyze_hlo(txt)
    assert cost.collective_bytes["all-reduce"] == 128 * 256 * 4


def test_hlo_analyzer_dus_in_place():
    txt = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main.1 (p0: f32[1024,64], p1: f32[1,64], p2: s32[]) -> f32[1024,64] {
  %p0 = f32[1024,64]{1,0} parameter(0)
  %p1 = f32[1,64]{1,0} parameter(1)
  %p2 = s32[] parameter(2)
  ROOT %dynamic-update-slice.1 = f32[1024,64]{1,0} dynamic-update-slice(%p0, %p1, %p2, %p2)
}
"""
    cost = analyze_hlo(txt)
    assert cost.bytes == 2 * 64 * 4       # slice in/out, not the full buffer
