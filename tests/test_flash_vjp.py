"""Flash custom-VJP: gradients match autodiff of reference attention."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced_config
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import full_attention


def _cfg(softcap=0.0):
    import dataclasses
    cfg = reduced_config(get_config("qwen2.5-14b"))
    return dataclasses.replace(cfg, attn_softcap=softcap)


@pytest.mark.parametrize("b,s,h,kv,d,window,cap", [
    (2, 96, 4, 2, 16, 0, 0.0),
    (1, 64, 4, 4, 16, 16, 0.0),
    (1, 80, 2, 1, 32, 0, 20.0),
    (2, 64, 4, 2, 16, 24, 20.0),
])
def test_flash_vjp_grads_match_reference(b, s, h, kv, d, window, cap):
    cfg = _cfg(cap)
    key = jax.random.PRNGKey(s + h)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    cot = jax.random.normal(ks[3], (b, s, h, d), jnp.float32)

    def loss_flash(q, k, v):
        out = full_attention(cfg, q, k, v, mask_kind="window",
                             window=window, block_size=32,
                             use_flash_vjp=True)
        return jnp.sum(out * cot)

    def loss_ref(q, k, v):
        out = attention_ref(q, k, v, causal=True, window=window,
                            softcap=cap)
        return jnp.sum(out * cot)

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_flash, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=3e-4, rtol=1e-3)


def test_flash_vjp_traced_window_grads():
    """Per-layer traced windows (gemma2 alternation) differentiate cleanly."""
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 48, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 48, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 48, 2, 16), jnp.float32)

    def loss(q, flag):
        window = jnp.where(flag, jnp.float32(2 ** 30), jnp.float32(8))
        out = full_attention(cfg, q, k, v, mask_kind="window",
                             window=window, block_size=16)
        return jnp.sum(out ** 2)

    for flag in (True, False):
        g = jax.grad(loss)(q, jnp.asarray(flag))
        assert np.isfinite(np.asarray(g)).all()
    # flag changes the function (different mask)
    assert abs(float(loss(q, jnp.asarray(True)))
               - float(loss(q, jnp.asarray(False)))) > 1e-3


def test_forward_identical_with_and_without_vjp():
    cfg = _cfg(30.0)
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 16), jnp.float32)
    a = full_attention(cfg, q, k, v, block_size=32, use_flash_vjp=True)
    b = full_attention(cfg, q, k, v, block_size=32, use_flash_vjp=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
