"""LM architecture smoke tests (deliverable (f)): every assigned arch at a
reduced config runs one train step + prefill + decode on CPU with finite
outputs and correct shapes; decode agrees with full re-forward."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, cell_skips, get_config, list_archs, \
    reduced_config
from repro.models import build_model
from repro.models.moe import moe_apply, moe_params, moe_reference


def _batch(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32))}
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.asarray(
            0.1 * rng.standard_normal((b, cfg.n_frontend_tokens,
                                       cfg.d_model)), jnp.bfloat16)
    if cfg.is_encdec:
        batch["src_embeds"] = jnp.asarray(
            0.1 * rng.standard_normal((b, 8, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_step(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 16)
    loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_prefill_decode_shapes(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(1))
    b, s = 2, 12
    batch = _batch(cfg, b, s, seed=1)
    extra = cfg.n_frontend_tokens if cfg.frontend == "vision_patches" else 0
    logits, cache = model.prefill(params, batch, max_len=s + extra + 8)
    assert logits.shape == (b, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = model.decode_step(params, cache, tok)
    assert logits2.shape == (b, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # padded vocab positions never win
    assert int(jnp.argmax(logits2, -1).max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ["gemma2-2b", "rwkv6-1.6b", "hymba-1.5b",
                                  "qwen2.5-14b"])
def test_decode_matches_full_forward(arch):
    """prefill(t[:n]) + decode(t[n]) logits == prefill(t[:n+1]) logits."""
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (1, 10)).astype(np.int32)
    logits_a, cache = model.prefill(params, {"tokens": jnp.asarray(
        toks[:, :9])}, max_len=16)
    step_logits, _ = model.decode_step(params, cache,
                                       jnp.asarray(toks[:, 9:10]))
    full_logits, _ = model.prefill(params, {"tokens": jnp.asarray(toks)},
                                   max_len=16)
    np.testing.assert_allclose(np.asarray(step_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=0.15, rtol=0.05)


def test_moe_capacity_matches_reference():
    """With generous capacity the sorted dispatch equals the exact mixture."""
    cfg = reduced_config(get_config("granite-moe-1b-a400m"))
    p_tpl = moe_params(cfg)
    from repro.models.layers import init_params
    p = init_params(p_tpl, jax.random.PRNGKey(3))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.d_model))
    got = moe_apply(cfg, p, x, capacity_factor=8.0)
    ref = moe_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


def test_sliding_window_matches_reference():
    from repro.models.attention import full_attention
    from repro.kernels.flash_attention.ref import attention_ref
    cfg = reduced_config(get_config("gemma2-2b"))
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    out = full_attention(cfg, q, k, v, mask_kind="window", window=8,
                         block_size=32)
    ref = attention_ref(q, k, v, causal=True, window=8,
                        softcap=cfg.attn_softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_cell_skips_documented():
    skips = cell_skips()
    assert len(skips) == 8
    assert ("hymba-1.5b", "long_500k") not in skips
    assert ("rwkv6-1.6b", "long_500k") not in skips
    assert all(shape == "long_500k" for _, shape in skips)


def test_int8_kv_cache_decode_close_to_bf16():
    cfg = reduced_config(get_config("qwen2.5-14b"))
    m16 = build_model(cfg, remat=False)
    m8 = build_model(cfg, remat=False, kv_cache_dtype=jnp.int8)
    params = m16.init_params(jax.random.PRNGKey(6))
    toks = jnp.asarray(np.random.default_rng(6).integers(
        0, cfg.vocab_size, (1, 9)).astype(np.int32))
    _, c16 = m16.prefill(params, {"tokens": toks}, max_len=16)
    _, c8 = m8.prefill(params, {"tokens": toks}, max_len=16)
    nxt = jnp.asarray([[5]], jnp.int32)
    l16, _ = m16.decode_step(params, c16, nxt)
    l8, _ = m8.decode_step(params, c8, nxt)
    # int8 KV is approximate but must keep the same top prediction
    assert int(jnp.argmax(l16)) == int(jnp.argmax(l8))
