"""Partitioned-table sharded execution + zone-map partition pruning.

Four layers:

1. **Zone maps / PartitionedTable units** — registration-time collection
   (min/max, small-domain bitsets, null counts), ragged tails, all-NULL
   partitions.
2. **Morsel scheduler units** — one shared pow-2 bucket whatever the
   partition/device ratio, partitions never split, LPT balance, waves when
   partitions exceed devices, empty placements.
3. **Pruning soundness** (hypothesis when available, plus deterministic
   pinned cases): a pruned partition never contains a valid row satisfying
   the predicate, and sharded pruned execution is bit-exact on valid rows
   against unpruned single-device execution — including all-NULL and
   single-row partitions.
4. **Service integration** — `ExecutionConfig(sharded=True)` routes
   row-local plans over partitioned catalog tables through the sharded
   executor; warm repeats compile nothing; caller-supplied override tables
   never prune or shard; `shard_info()`/`OptimizationReport` ledgers.
"""

import numpy as np
import pytest

from repro.core import (CrossOptimizer, ExecutionConfig, ModelStore,
                        OptimizerConfig, compile_plan)
from repro.core.cost_model import estimate_rows
from repro.core.ir import Plan, plan_signature
from repro.core.partition import PartitionedTable
from repro.relational.expr import col
from repro.relational.table import Table
from repro.serve import PredictionService, plan_morsels
from repro.serve.sharded import ShardedExecutor

pytestmark = pytest.mark.tier1


def _table(values, valid=None, **extra):
    cols = {"a": np.asarray(values)}
    for k, v in extra.items():
        cols[k] = np.asarray(v)
    t = Table.from_pydict(cols)
    if valid is not None:
        t = t.with_valid(np.asarray(valid, bool))
    return t


def _filter_plan(pred) -> Plan:
    plan = Plan()
    s = plan.emit("scan", "RA", [], "table", table="t")
    plan.output = plan.emit("filter", "RA", [s], "table", predicate=pred)
    return plan


def _optimize(store, plan, **cfg):
    return CrossOptimizer(store, OptimizerConfig(**cfg)).optimize(plan)


def _valid_rows(table: Table):
    mask = np.asarray(table.valid)
    return {k: np.asarray(v)[mask] for k, v in table.columns.items()}


def _assert_same_valid_rows(got: Table, want: Table):
    g, w = _valid_rows(got), _valid_rows(want)
    assert set(g) == set(w)
    for k in w:
        assert g[k].shape == w[k].shape, k
        assert (g[k] == w[k]).all(), k


# ---------------------------------------------------------------------------
# 1. Zone maps / PartitionedTable
# ---------------------------------------------------------------------------

def test_zone_maps_collect_min_max_domain_and_nulls():
    t = _table([0, 1, 2, 10, 11, 12, 20, 21],
               valid=[1, 1, 1, 1, 0, 1, 0, 0],
               b=np.linspace(0.0, 7.0, 8).astype(np.float32))
    pt = PartitionedTable.build(t, partition_rows=3)
    assert pt.n_partitions == 3
    assert [p.n_rows for p in pt.partitions] == [3, 3, 2]   # ragged tail
    z0 = pt.partitions[0].zone
    assert (z0.columns["a"].min, z0.columns["a"].max) == (0.0, 2.0)
    assert z0.columns["a"].domain == frozenset((0.0, 1.0, 2.0))
    assert z0.null_count == 0
    z1 = pt.partitions[1].zone
    assert z1.null_count == 1
    assert z1.columns["a"].domain == frozenset((10.0, 12.0))  # valid only
    z2 = pt.partitions[2].zone                                # all-NULL
    assert z2.n_valid == 0
    assert z2.columns["a"].min is None
    # float columns keep min/max but no exact domain
    assert z0.columns["b"].domain is None


def test_partition_slices_reassemble_the_table():
    """`PartitionedTable.slice` / `Table.row_slice` — the public partition
    accessor: per-partition slices concatenate back to the base table."""
    t = _table(np.arange(11), valid=[1, 0, 1] * 3 + [1, 1],
               b=np.linspace(0, 1, 11).astype(np.float32))
    pt = PartitionedTable.build(t, partition_rows=4)
    got_cols = {k: [] for k in t.columns}
    got_valid = []
    for p in pt.partitions:
        piece = pt.slice(p.index)
        assert piece.capacity == p.n_rows
        assert piece.schema is t.schema
        for k in t.columns:
            got_cols[k].append(np.asarray(piece.columns[k]))
        got_valid.append(np.asarray(piece.valid))
    for k in t.columns:
        assert (np.concatenate(got_cols[k])
                == np.asarray(t.columns[k])).all(), k
    assert (np.concatenate(got_valid) == np.asarray(t.valid)).all()


def test_nan_rows_disable_zone_stats_not_pruning():
    """NaN poisons ordered stats (min/max propagate it, and a NaN row
    *satisfies* `!=`): a float partition containing NaN publishes no
    stats and must survive every constraint."""
    values = [np.nan, 10.0, 50.0, 60.0]
    t = _table(np.asarray(values, np.float32))
    pt = PartitionedTable.build(t, partition_rows=2)
    z0 = pt.partitions[0].zone.columns["a"]
    assert z0.min is None and z0.max is None          # stats withheld
    from repro.relational.expr import extract_constraints
    for pred in (col("a") < 25, col("a") != 10.0, col("a") == 10.0):
        surv, pruned = pt.prune(extract_constraints(pred))
        assert 0 in surv, f"NaN partition pruned under {pred!r}"
    # the NaN-free partition still prunes normally
    surv, pruned = pt.prune(extract_constraints(col("a") < 25))
    assert 1 in pruned
    # end-to-end: the valid row 10.0 must appear in sharded output
    _check_prune_sound_and_bit_exact(
        np.asarray(values, np.float32), None, col("a") < 25, 2)


def test_partitions_must_tile_the_table():
    t = _table([1, 2, 3, 4])
    pt = PartitionedTable.build(t, partition_rows=2)
    with pytest.raises(ValueError):
        PartitionedTable(t, pt.partitions[:1])
    with pytest.raises(ValueError):
        PartitionedTable.build(t, partition_rows=0)


def test_prune_is_conservative_and_exact_on_domains():
    t = _table([0, 1, 5, 6, 7, 9], valid=[1, 1, 1, 1, 0, 0])
    pt = PartitionedTable.build(t, partition_rows=2)
    surv, pruned = pt.prune([])
    assert pruned == (2,)                  # all-NULL prunes unconditionally
    from repro.relational.expr import extract_constraints
    cons = extract_constraints((col("a") == 5) & (col("a") >= 0))
    surv, pruned = pt.prune(cons)
    assert surv == (1,) and 0 in pruned    # domain {0,1} excludes 5


def test_register_table_partitioned_roundtrip():
    store = ModelStore()
    t = _table(np.arange(10))
    store.register_table("t", t, partition_rows=4)
    pt = store.get_partitioned("t")
    assert pt is not None and pt.n_partitions == 3
    assert store.get_table("t") is pt.table
    # re-registering unpartitioned drops zone maps
    store.register_table("t", t)
    assert store.get_partitioned("t") is None
    # a pre-built PartitionedTable registers as-is
    store.register_table("t", PartitionedTable.build(t, 5))
    assert store.get_partitioned("t").n_partitions == 2


# ---------------------------------------------------------------------------
# 2. Morsel scheduler
# ---------------------------------------------------------------------------

def test_morsels_share_one_bucket_and_never_split_partitions():
    sizes = [(i, r) for i, r in enumerate([100, 100, 100, 100, 37, 100])]
    pl = plan_morsels(sizes, n_devices=2, min_bucket_rows=8)
    assert pl.total_rows == 537
    seen = [i for dev in pl.assignments for m in dev for i in m.partitions]
    assert sorted(seen) == list(range(6))             # every partition once
    for dev in pl.assignments:
        for m in dev:
            assert m.rows <= pl.bucket_rows
    # bucket covers the ideal per-device share, pow-2
    assert pl.bucket_rows >= 537 / 2
    assert pl.bucket_rows & (pl.bucket_rows - 1) == 0


def test_morsel_waves_when_partitions_exceed_devices():
    sizes = [(i, 64) for i in range(16)]
    pl = plan_morsels(sizes, n_devices=4, min_bucket_rows=8,
                      morsel_rows=128)          # cap -> 2 partitions/morsel
    assert pl.bucket_rows == 128
    assert pl.n_morsels == 8
    assert pl.n_waves == 2                      # 8 morsels over 4 devices
    loads = [sum(m.rows for m in dev) for dev in pl.assignments]
    assert max(loads) == min(loads) == 256      # LPT balances exactly here


def test_morsel_bucket_fits_largest_partition():
    pl = plan_morsels([(0, 10), (1, 1000)], n_devices=4,
                      min_bucket_rows=8, morsel_rows=64)
    assert pl.bucket_rows >= 1000               # partitions are atomic


def test_empty_placement():
    pl = plan_morsels([], n_devices=3)
    assert pl.n_morsels == 0 and pl.n_waves == 0 and pl.total_rows == 0


# ---------------------------------------------------------------------------
# 3. Pruning soundness + bit-exactness (deterministic pinned cases)
# ---------------------------------------------------------------------------

def _check_prune_sound_and_bit_exact(values, valid, pred, partition_rows):
    store = ModelStore()
    t = _table(values, valid=valid)
    store.register_table("t", t, partition_rows=partition_rows)
    pt = store.get_partitioned("t")
    plan = _filter_plan(pred)
    opt, report = _optimize(store, plan)
    scan = opt.find("scan")[0]
    surviving = scan.attrs.get("partitions")
    oracle = np.asarray(pred.evaluate(
        {k: np.asarray(v) for k, v in t.columns.items()})).astype(bool)
    oracle &= np.asarray(t.valid)
    if surviving is not None:
        for p in pt.partitions:
            if p.index not in surviving:
                assert not oracle[p.start:p.stop].any(), \
                    f"pruned partition {p.index} has a matching valid row"
    # sharded pruned execution == valid rows of whole-table execution
    surv = surviving if surviving is not None \
        else tuple(range(pt.n_partitions))
    fn = compile_plan(opt, store)                # raw closure, no jit
    want = fn({"t": t})
    executor = ShardedExecutor()
    parts = [pt.partitions[i] for i in surv]
    placement = executor.plan(parts, min_bucket_rows=4)
    got = executor.execute(fn, pt, "t", parts, placement)
    _assert_same_valid_rows(got, want)


PINNED = [
    # (values, valid, predicate, partition_rows)
    ([0, 1, 2, 3, 4, 5, 6, 7], None, col("a") < 3, 2),
    ([0, 1, 2, 3], [0, 0, 0, 0], col("a") >= 0, 2),        # all-NULL table
    ([5, 5, 5, 9], [1, 1, 0, 1], col("a") == 5, 1),        # single-row parts
    ([1, 2, 3, 4, 5], [1, 0, 1, 0, 1], (col("a") > 1) & (col("a") <= 4), 2),
    ([3], [1], col("a") != 3, 1),                          # 1-row, 1-part
    ([0, 0, 0, 1, 1, 1], None, col("a") != 0, 3),          # domain != prune
    # float32 rounding: zone tests must compare in the runtime's float32
    # (0.1f > 0.1 in float64 would unsoundly prune the matching row)
    (np.asarray([0.1, 50.0], np.float32), None, col("a") <= 0.1, 1),
    (np.asarray([0.1, 0.3, 7.0, 9.0], np.float32), [1, 0, 1, 1],
     (col("a") > 0.1) & (col("a") < 8.5), 2),
]


@pytest.mark.parametrize("values,valid,pred,partition_rows", PINNED)
def test_pruning_pinned_cases(values, valid, pred, partition_rows):
    _check_prune_sound_and_bit_exact(values, valid, pred, partition_rows)


def test_pruning_composes_with_predicate_pushdown():
    """A filter that starts *above* a computed column still prunes: the
    pushdown rule moves it onto the scan first."""
    store = ModelStore()
    t = _table(np.arange(100))
    store.register_table("t", t, partition_rows=10)
    plan = Plan()
    s = plan.emit("scan", "RA", [], "table", table="t")
    m = plan.emit("map", "RA", [s], "table", name="twice",
                  expr=col("a") * 2)
    plan.output = plan.emit("filter", "RA", [m], "table",
                            predicate=col("a") < 25)
    opt, report = _optimize(store, plan)
    assert report.fired("predicate_pushdown")
    assert report.fired("partition_pruning")
    assert report.partitions["t"] == (3, 10)


def test_pruning_respects_disable_flag_and_consumer_forks():
    store = ModelStore()
    t = _table(np.arange(40))
    store.register_table("t", t, partition_rows=10)
    plan = _filter_plan(col("a") < 5)
    opt, report = _optimize(store, plan, enable_partition_pruning=False)
    assert "partitions" not in opt.find("scan")[0].attrs
    # fork: a second consumer of the scan sees unfiltered rows -> no prune
    plan = _filter_plan(col("a") < 5)
    scan_id = plan.find("scan")[0].id
    plan.output = plan.emit("union", "RA", [plan.output, scan_id], "table")
    opt, report = _optimize(store, plan)
    assert "partitions" not in opt.find("scan")[0].attrs


def test_partition_aware_signatures_and_row_estimates():
    store = ModelStore()
    t = _table(np.sort(np.arange(100) % 50))
    store.register_table("t", t, partition_rows=10)
    opt_a, _ = _optimize(store, _filter_plan(col("a") < 10))
    opt_b, _ = _optimize(store, _filter_plan(col("a") < 10))
    opt_c, _ = _optimize(store, _filter_plan(col("a") < 10),
                         enable_partition_pruning=False)
    assert plan_signature(opt_a) == plan_signature(opt_b)
    assert plan_signature(opt_a) != plan_signature(opt_c)
    scan = opt_a.find("scan")[0]
    rows = estimate_rows(opt_a, store)
    surv = scan.attrs["partitions"]
    assert rows[scan.id] == 10.0 * len(surv)      # partition-count-aware


# ---------------------------------------------------------------------------
# 3b. Hypothesis property (skipped where hypothesis is absent; the pinned
#     cases above cover the named edge cases regardless)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    _OPS = ["==", "!=", "<", "<=", ">", ">="]

    def _mk_pred(spec):
        out = None
        for op, value in spec:
            c = col("a")
            term = {"==": c == value, "!=": c != value, "<": c < value,
                    "<=": c <= value, ">": c > value, ">=": c >= value}[op]
            out = term if out is None else out & term
        return out

    @given(
        values=st.lists(st.integers(min_value=-4, max_value=4),
                        min_size=1, max_size=24),
        valid_bits=st.lists(st.booleans(), min_size=24, max_size=24),
        partition_rows=st.integers(min_value=1, max_value=9),
        spec=st.lists(st.tuples(st.sampled_from(_OPS),
                                st.integers(min_value=-5, max_value=5)),
                      min_size=1, max_size=3),
    )
    @settings(max_examples=60, deadline=None)
    def test_pruned_partition_never_holds_matching_row(
            values, valid_bits, partition_rows, spec):
        _check_prune_sound_and_bit_exact(
            values, valid_bits[:len(values)], _mk_pred(spec),
            partition_rows)


def test_pruning_randomized_sweep():
    """Seeded twin of the hypothesis property (mirrors the convention of
    ``test_serving_properties``: the sweep runs even where hypothesis is
    absent — change both together)."""
    rng = np.random.RandomState(42)
    ops = ["==", "!=", "<", "<=", ">", ">="]
    for _ in range(40):
        n = int(rng.randint(1, 25))
        values = rng.randint(-4, 5, n)
        valid = rng.rand(n) < rng.choice([0.0, 0.5, 1.0])
        partition_rows = int(rng.randint(1, 10))
        spec = [(ops[rng.randint(len(ops))], int(rng.randint(-5, 6)))
                for _ in range(rng.randint(1, 4))]
        pred = None
        for op, v in spec:
            c = col("a")
            term = {"==": c == v, "!=": c != v, "<": c < v,
                    "<=": c <= v, ">": c > v, ">=": c >= v}[op]
            pred = term if pred is None else pred & term
        _check_prune_sound_and_bit_exact(values, valid, pred,
                                         partition_rows)


# ---------------------------------------------------------------------------
# 4. Service integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def partitioned_store():
    from repro.ml import (LogisticRegression, Pipeline, PipelineMetadata,
                          StandardScaler)
    rng = np.random.RandomState(0)
    n = 2000
    age = np.sort(rng.randint(0, 100, n))          # clustered on age
    x = rng.randn(n).astype(np.float32)
    t = Table.from_pydict({"pid": np.arange(n), "age": age, "x": x})
    store = ModelStore()
    store.register_table("people", t, partition_rows=200)
    data = {"age": age.astype(np.float32), "x": x}
    sc = StandardScaler(["age", "x"]).fit(data)
    pipe = Pipeline([sc], LogisticRegression(steps=15),
                    PipelineMetadata(name="m", task="classification"))
    pipe.fit(data, (age > 50).astype(np.int32))
    store.register_model("m", pipe)
    return store, t


SQL = "SELECT pid, PREDICT(MODEL='m') AS s FROM people WHERE age < 30"


def _sharded_service(store, **knobs):
    return PredictionService(store, execution_config=ExecutionConfig(
        sharded=True, shard_min_bucket_rows=32, **knobs))


def test_service_sharded_bit_exact_and_pruned(partitioned_store):
    store, _ = partitioned_store
    base = PredictionService(store)
    svc = _sharded_service(store)
    want = base.run(SQL)
    got = svc.run(SQL)
    _assert_same_valid_rows(got, want)
    info = svc.shard_info()
    assert info["enabled"] and info["sharded_executions"] == 1
    assert info["partitions_pruned"] >= 5          # age-clustered: most skip
    assert got.capacity < want.capacity            # pruned rows not placed
    base.close(); svc.close()


def test_service_sharded_zero_compiles_on_warm_repeat(partitioned_store):
    store, _ = partitioned_store
    svc = _sharded_service(store)
    svc.run(SQL)
    before = (svc.stats.cache_misses, svc.stats.shard_compiles,
              svc.stats.jit_traces)
    for _ in range(3):
        svc.run(SQL)
    after = (svc.stats.cache_misses, svc.stats.shard_compiles,
             svc.stats.jit_traces)
    assert before == after
    assert svc.stats.shard_hits >= 3
    svc.close()


def test_service_sharded_unpruned_full_bit_exact(partitioned_store):
    store, _ = partitioned_store
    sql = "SELECT pid, PREDICT(MODEL='m') AS s FROM people"
    base = PredictionService(store)
    svc = _sharded_service(store)
    want, got = base.run(sql), svc.run(sql)
    assert got.capacity == want.capacity           # nothing pruned
    assert (np.asarray(got.valid) == np.asarray(want.valid)).all()
    for k in want.columns:
        assert (np.asarray(got.columns[k])
                == np.asarray(want.columns[k])).all(), k
    base.close(); svc.close()


def test_service_sharded_capture_populates_result_cache(partitioned_store):
    """Sharded execution used to drop the capture output with ``unwrap``,
    so a sharded full scan never populated the result cache.  The executor
    now reassembles per-morsel capture slices in partition order; the
    stored value is bit-exact the whole-table serve's capture, so a second
    query splices from it."""
    store, _ = partitioned_store
    sql = "SELECT pid, PREDICT(MODEL='m') AS s FROM people"
    svc = _sharded_service(store)
    svc.run(sql)
    assert svc.stats.sharded_executions == 1
    assert svc.stats.result_puts == 1
    out = svc.run("SELECT pid, x, PREDICT(MODEL='m') AS s FROM people")
    assert svc.stats.result_hits == 1
    assert svc.stats.spliced_executions == 1
    base = PredictionService(store)   # unsharded, uncached reference
    want = base.run("SELECT pid, x, PREDICT(MODEL='m') AS s FROM people")
    _assert_same_valid_rows(out, want)
    base.close(); svc.close()


def test_service_sharded_pruned_serve_skips_capture(partitioned_store):
    """When zone maps pruned partitions the reassembled capture covers
    only surviving rows — not the value the result-cache key claims — so
    it must be discarded, never stored."""
    store, _ = partitioned_store
    svc = _sharded_service(store)
    svc.run(SQL)                                   # age < 30: prunes
    assert svc.shard_info()["partitions_pruned"] > 0
    assert svc.stats.result_puts == 0
    svc.close()


def test_service_override_tables_never_prune_or_shard(partitioned_store):
    store, t = partitioned_store
    svc = _sharded_service(store)
    # rows that the catalog zone maps would prune away must still be served
    # when the caller supplies their own table
    sub = Table({k: v[-64:] for k, v in t.columns.items()},
                t.valid[-64:], t.schema)
    out = svc.run(SQL, {"people": sub})
    assert out.capacity == 64
    assert svc.stats.sharded_executions == 0
    assert "partitions" not in [a for n in svc.compile(
        SQL, {"people": sub}).plan.nodes.values()
        for a in n.attrs]
    svc.close()


def test_service_all_partitions_pruned(partitioned_store):
    store, _ = partitioned_store
    svc = _sharded_service(store)
    out = svc.run("SELECT pid, PREDICT(MODEL='m') AS s FROM people "
                  "WHERE age < 0")
    assert out.capacity == 0
    assert svc.shard_info()["prune_rate"] == 1.0
    svc.close()


def test_stale_pruning_falls_back_to_full_scan():
    """A table re-registered between compile and execute (invalidation
    evicts the cache entry, but an in-flight execution can already hold
    it) may keep its partition *count* while its data changed — the
    version snapshot must void the stale pruned-partition set."""
    store = ModelStore()
    rng = np.random.RandomState(1)
    t1 = _table(np.sort(rng.randint(0, 100, 400)))     # clustered
    store.register_table("t", t1, partition_rows=50)
    svc = PredictionService(store, execution_config=ExecutionConfig(
        sharded=True, shard_min_bucket_rows=16))
    plan = _filter_plan(col("a") < 20)
    compiled = svc.compile(plan)
    scan = compiled.plan.find("scan")[0]
    stale = scan.attrs["partitions"]
    assert len(stale) < 8                              # pruning happened
    # same partition count, inverted clustering: the stale set is wrong
    t2 = _table(np.sort(rng.randint(0, 100, 400))[::-1].copy())
    store.register_table("t", t2, partition_rows=50)
    out = svc._execute_sharded(compiled, {"t": t2})
    assert svc.stats.partitions_scanned == 8           # full scan fallback
    want = np.asarray(t2.column("a"))[np.asarray(t2.column("a")) < 20]
    got = np.asarray(out.column("a"))[np.asarray(out.valid)]
    assert got.shape == want.shape and (got == want).all()
    # partitioning dropped entirely mid-flight: whole-table fallback, not
    # a crash
    store.register_table("t", t2)                      # unpartitioned
    out = svc._execute_sharded(compiled, {"t": t2})
    got = np.asarray(out.column("a"))[np.asarray(out.valid)]
    assert (got == want).all()
    svc.close()


def test_sharded_config_is_part_of_the_cache_key(partitioned_store):
    store, _ = partitioned_store
    svc1 = PredictionService(store)
    c1 = svc1.compile(SQL)
    svc2 = _sharded_service(store)
    c2 = svc2.compile(SQL)
    assert c1.key != c2.key
    svc1.close(); svc2.close()
