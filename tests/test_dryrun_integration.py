"""Dry-run integration: one real (arch x shape x mesh) cell lowered and
compiled on 512 placeholder devices, in a subprocess (so this test session's
jax stays at 1 CPU device)."""

import json
import subprocess
import sys
from pathlib import Path

import pytest


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "granite-moe-1b-a400m", "--shape", "prefill_32k",
           "--out", str(tmp_path)]
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=540,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:"
                               "/usr/local/bin"},
                          cwd=str(Path(__file__).resolve().parents[1]))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    out = json.loads(
        (tmp_path / "granite-moe-1b-a400m__prefill_32k__single.json")
        .read_text())
    assert out["status"] == "ok"
    assert out["n_chips"] == 256
    r = out["roofline"]
    assert r["compute_s"] > 0 and r["memory_s"] > 0
    assert out["hlo_cost_per_device"]["collective_bytes"]


@pytest.mark.slow
def test_dryrun_skip_cell_documented(tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "gemma2-2b", "--shape", "long_500k",
           "--out", str(tmp_path)]
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=120,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                          cwd=str(Path(__file__).resolve().parents[1]))
    assert proc.returncode == 0
    out = json.loads((tmp_path / "gemma2-2b__long_500k__single.json")
                     .read_text())
    assert out["status"] == "skipped"
    assert "sub-quadratic" in out["reason"]
