"""Prediction-query serving layer: plan-signature cache, chunked execution,
micro-batch coalescing.

Key guarantees under test:
- a repeat of an identical query performs ZERO plan compilations (asserted
  through the ``codegen`` compile-counter hook);
- the plan signature is invariant to node-id aliasing and table column
  order, but sensitive to model *content* (retrained weights miss the cache);
- chunked (morsel) execution is bit-exact vs whole-table execution,
  including ragged tails;
- concurrent requests sharing a signature coalesce into one execution.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import ModelStore, parse_query
from repro.core import codegen
from repro.core.codegen import add_compile_listener
from repro.core.ir import Category, Node, Plan, plan_signature
from repro.core.model_store import content_fingerprint
from repro.data import hospital_tables
from repro.ml import DecisionTree, Pipeline, PipelineMetadata, StandardScaler
from repro.relational.table import Table
from repro.relational.expr import col
from repro.serve import PredictionService

N_ROWS = 600
FEATS = ["age", "gender", "pregnant", "rcount"]
SQL = ("SELECT pid, age, PREDICT(MODEL='los_pi') AS los "
       "FROM patient_info WHERE age > 30")


def _pipeline(data, max_depth=6):
    sc = StandardScaler(FEATS).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression",
                                       max_depth=max_depth),
                    PipelineMetadata(name="los_pi", task="regression"))
    pipe.fit({k: data[k] for k in FEATS}, data["length_of_stay"])
    return pipe


@pytest.fixture(scope="module")
def store():
    store = ModelStore()
    for n, t in hospital_tables(N_ROWS, seed=7).items():
        store.register_table(n, t)
    pi = store.get_table("patient_info")
    data = {c: np.asarray(pi.column(c)) for c in pi.names}
    store.register_model("los_pi", _pipeline(data))
    return store


def _sub_table(table: Table, lo: int, hi: int) -> Table:
    return Table({k: v[lo:hi] for k, v in table.columns.items()},
                 table.valid[lo:hi], table.schema)


def _table_arrays(t: Table):
    return ({k: np.asarray(v) for k, v in t.columns.items()},
            np.asarray(t.valid))


# ---------------------------------------------------------------------------
# Cache behaviour
# ---------------------------------------------------------------------------

def test_second_run_zero_plan_compiles(store):
    service = PredictionService(store)
    compiled_plans = []
    unsubscribe = add_compile_listener(compiled_plans.append)
    try:
        out1 = service.run(SQL)
        assert len(compiled_plans) == 1
        assert service.stats.cache_misses == 1
        out2 = service.run(SQL)                # warm: zero compilations
        assert len(compiled_plans) == 1
        assert service.stats.cache_hits == 1
    finally:
        unsubscribe()
    c1, v1 = _table_arrays(out1)
    c2, v2 = _table_arrays(out2)
    assert (v1 == v2).all()
    for k in c1:
        assert (c1[k] == c2[k]).all()


def test_compile_counter_counts(store):
    before = codegen.compile_stats["plans_compiled"]
    service = PredictionService(store)
    service.run(SQL)
    service.run(SQL)
    service.run(SQL)
    assert codegen.compile_stats["plans_compiled"] == before + 1


def test_lru_eviction(store):
    service = PredictionService(store, max_cache_entries=2)
    service.run("SELECT pid FROM patient_info WHERE age > 10")
    service.run("SELECT pid FROM patient_info WHERE age > 20")
    service.run("SELECT pid FROM patient_info WHERE age > 30")
    info = service.cache_info()
    assert info["entries"] == 2
    assert info["evictions"] == 1


# ---------------------------------------------------------------------------
# Signature semantics
# ---------------------------------------------------------------------------

def test_signature_invariant_to_node_id_aliases(store):
    """The same logical plan built under different node ids (the SQL
    frontend's fresh-id counter, or hand-chosen aliases) hashes identically."""
    p1 = parse_query(SQL, store)
    p2 = parse_query(SQL, store)        # fresh auto-generated ids
    assert plan_signature(p1) == plan_signature(p2)

    def hand_built(alias: str) -> Plan:
        plan = Plan()
        scan = plan.add(Node("scan", Category.RA, [], {"table": "patient_info"},
                             "table", id=f"{alias}_scan"))
        filt = plan.add(Node("filter", Category.RA, [scan],
                             {"predicate": col("age") > 30}, "table",
                             id=f"{alias}_filter"))
        plan.output = filt
        return plan

    assert plan_signature(hand_built("a")) == plan_signature(hand_built("zz"))


def test_signature_invariant_to_column_order(store):
    """Cache keys hash table schemas sorted by column name, so two catalogs
    whose tables declare the same columns in different order share keys."""
    pi = store.get_table("patient_info")
    names = list(pi.names)
    reordered = Table({n: pi.columns[n] for n in reversed(names)},
                      pi.valid, pi.schema.select(list(reversed(names))))
    other = ModelStore()
    other.register_table("patient_info", reordered)
    other.register_model("los_pi", store.get_model("los_pi"))

    s1 = PredictionService(store)
    s2 = PredictionService(other)
    k1, _ = s1._cache_key(parse_query(SQL, store), None)
    k2, _ = s2._cache_key(parse_query(SQL, other), None)
    assert k1 == k2


def test_signature_sensitive_to_model_content(store):
    pi = store.get_table("patient_info")
    data = {c: np.asarray(pi.column(c)) for c in pi.names}
    retrained = _pipeline(data, max_depth=3)

    other = ModelStore()
    other.register_table("patient_info", pi)
    other.register_model("los_pi", retrained)

    sig_orig = plan_signature(parse_query(SQL, store))
    sig_new = plan_signature(parse_query(SQL, other))
    assert sig_orig != sig_new
    assert content_fingerprint(store.get_model("los_pi")) \
        != content_fingerprint(retrained)
    # byte-identical re-registration digests identically
    v2 = other.register_model("los_pi", retrained)
    assert other.model_digest("los_pi", 1) == other.model_digest("los_pi", v2)


def test_udf_signature_sensitive_to_constants_and_closures(store):
    """co_code alone cannot distinguish `+1` from `+2` (the constant lives
    in co_consts) — the signature must."""
    def build(fn):
        plan = Plan()
        scan = plan.emit("scan", Category.RA, [], "table",
                         table="patient_info")
        plan.output = plan.emit("udf", Category.UDF, [scan], "vector", fn=fn)
        return plan

    s_plus1 = plan_signature(build(lambda cols: cols["age"] + 1))
    s_plus2 = plan_signature(build(lambda cols: cols["age"] + 2))
    assert s_plus1 != s_plus2

    def closed_over(k):
        return lambda cols: cols["age"] + k

    assert plan_signature(build(closed_over(3))) \
        != plan_signature(build(closed_over(4)))


def test_fingerprint_covers_globals_and_private_attrs():
    """Identical bytecode must not collide: the referenced global name
    (abs vs len, np.log vs np.exp) and underscored fitted state (e.g.
    Bucketizer._kept) are part of an artifact's content."""
    assert content_fingerprint(lambda x: abs(x)) \
        != content_fingerprint(lambda x: len(x))

    def log_udf(cols):
        return np.log(cols["age"])

    def exp_udf(cols):
        return np.exp(cols["age"])

    assert content_fingerprint(log_udf) != content_fingerprint(exp_udf)

    class Fitted:
        def __init__(self, w):
            self._w = w

    assert content_fingerprint(Fitted(1)) != content_fingerprint(Fitted(2))
    # ...and constants inside *nested* functions
    assert content_fingerprint(lambda cols: (lambda v: v + 1)(cols)) \
        != content_fingerprint(lambda cols: (lambda v: v + 2)(cols))


def test_zero_cache_entries_disables_caching(store):
    service = PredictionService(store, max_cache_entries=0)
    sql = "SELECT pid FROM patient_info WHERE age > 10"
    out1 = service.run(sql)
    out2 = service.run(sql)
    assert service.cache_info()["entries"] == 0
    assert (np.asarray(out1.valid) == np.asarray(out2.valid)).all()


def test_stats_update_invalidates_cache_key(store):
    """Stats-based pruning bakes catalog stats into the executable, so
    re-registering a table with different stats must miss the cache."""
    other = ModelStore()
    pi = store.get_table("patient_info")
    other.register_table("patient_info", pi)
    other.register_model("los_pi", store.get_model("los_pi"))
    service = PredictionService(other)
    k1, _ = service._cache_key(parse_query(SQL, other), None)
    wider = pi.with_columns({"age": np.asarray(pi.column("age")) + 100})
    other.register_table("patient_info", wider)
    k2, _ = service._cache_key(parse_query(SQL, other), None)
    assert k1 != k2


def test_override_tables_bypass_stats_pruning(store):
    """Caller-supplied tables may violate catalog stats; predictions must
    match an unpruned execution even for out-of-range rows."""
    from repro.core import OptimizerConfig
    pi = store.get_table("patient_info")
    out_of_range = pi.with_columns(
        {"age": np.asarray(pi.column("age"), np.float32) + 500.0})
    service = PredictionService(store)
    sql = "SELECT pid, PREDICT(MODEL='los_pi') AS los FROM patient_info"
    got = service.run(sql, {"patient_info": out_of_range})

    unpruned = PredictionService(
        store, optimizer_config=OptimizerConfig(enable_model_pruning=False))
    want = unpruned.run(sql, {"patient_info": out_of_range})
    cg, vg = _table_arrays(got)
    cw, vw = _table_arrays(want)
    assert (vg == vw).all()
    for k in cw:
        np.testing.assert_allclose(cg[k], cw[k], rtol=1e-6)


def test_optimizer_report_carries_signatures(store):
    from repro.core import CrossOptimizer
    plan = parse_query(SQL, store)
    _, report = CrossOptimizer(store).optimize(plan)
    assert report.input_signature == plan_signature(plan)
    assert report.plan_signature is not None
    assert report.referenced_models == ("los_pi",)


# ---------------------------------------------------------------------------
# Chunked (morsel) execution
# ---------------------------------------------------------------------------

def test_chunked_bit_exact_with_ragged_tail(store):
    whole = PredictionService(store)
    chunked = PredictionService(store, chunk_rows=128)   # 600 -> 4 + tail 88
    o1, o2 = whole.run(SQL), chunked.run(SQL)
    assert chunked.stats.chunks_executed == 5
    c1, v1 = _table_arrays(o1)
    c2, v2 = _table_arrays(o2)
    assert (v1 == v2).all()
    for k in c1:
        assert (c1[k] == c2[k]).all(), f"column {k} diverged under chunking"


def test_chunked_single_plan_compile(store):
    before = codegen.compile_stats["plans_compiled"]
    service = PredictionService(store, chunk_rows=100)
    service.run(SQL)
    service.run(SQL)
    assert codegen.compile_stats["plans_compiled"] == before + 1


def test_join_query_falls_back_to_whole_table(store):
    # hematocrit keeps the join alive through join-elimination
    sql = ("SELECT pid, hematocrit FROM patient_info JOIN blood_tests ON pid "
           "WHERE age > 30")
    service = PredictionService(store, chunk_rows=64)
    compiled = service.compile(sql)
    assert compiled.chunk_table is None      # join is not row-local
    out = service.run(sql)
    assert service.stats.chunks_executed == 0
    assert np.asarray(out.valid).any()


# ---------------------------------------------------------------------------
# Micro-batch admission
# ---------------------------------------------------------------------------

def test_coalesced_requests_single_execution(store):
    pi = store.get_table("patient_info")
    service = PredictionService(store)
    parts = [(0, 100), (100, 350), (350, 600)]
    tickets = [service.submit(SQL, {"patient_info": _sub_table(pi, lo, hi)})
               for lo, hi in parts]
    assert service.flush() == 3
    assert service.stats.batch_executions == 1
    assert service.stats.coalesced_requests == 2

    reference = PredictionService(store)
    for ticket, (lo, hi) in zip(tickets, parts):
        got = ticket.result()
        want = reference.run(SQL, {"patient_info": _sub_table(pi, lo, hi)})
        cg, vg = _table_arrays(got)
        cw, vw = _table_arrays(want)
        assert (vg == vw).all()
        for k in cw:
            assert (cg[k] == cw[k]).all()


def test_identical_catalog_requests_share_one_execution(store):
    service = PredictionService(store)
    t1 = service.submit(SQL)
    t2 = service.submit(SQL)
    t3 = service.submit(SQL)
    assert service.flush() == 3
    assert service.stats.batch_executions == 1
    assert service.stats.coalesced_requests == 2
    v1 = np.asarray(t1.result().valid)
    assert (v1 == np.asarray(t3.result().valid)).all()
    assert t2.done


@pytest.mark.timeout_guard(300)
def test_concurrent_run_threads(store):
    pi = store.get_table("patient_info")
    service = PredictionService(store)
    service.run(SQL)                         # warm the cache
    results = {}
    errors = []

    def worker(i, lo, hi):
        try:
            results[i] = service.run(
                SQL, {"patient_info": _sub_table(pi, lo, hi)})
        except Exception as e:               # pragma: no cover
            errors.append(e)

    spans = [(0, 200), (200, 400), (400, 600), (0, 600)]
    threads = [threading.Thread(target=worker, args=(i, lo, hi))
               for i, (lo, hi) in enumerate(spans)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(results) == 4
    reference = PredictionService(store)
    for i, (lo, hi) in enumerate(spans):
        want = reference.run(SQL, {"patient_info": _sub_table(pi, lo, hi)})
        cg, vg = _table_arrays(results[i])
        cw, vw = _table_arrays(want)
        assert (vg == vw).all()
        for k in cw:
            assert (cg[k] == cw[k]).all()


def test_failed_request_reports_error(store):
    service = PredictionService(store)
    ticket = service.submit("SELECT pid FROM no_such_table")
    service.flush()
    with pytest.raises(KeyError):
        ticket.result()


def test_ticket_result_timeout_raises(store):
    """Regression: an unserved ticket must raise TimeoutError on expiry,
    never silently return None (indistinguishable from a null result)."""
    service = PredictionService(store)
    ticket = service.submit(SQL)          # queued, deliberately not flushed
    t0 = time.perf_counter()
    with pytest.raises(TimeoutError):
        ticket.result(timeout=0.05)
    assert time.perf_counter() - t0 < 5.0
    assert not ticket.done
    service.flush()                       # same ticket still serveable after
    out = ticket.result(timeout=30.0)
    assert np.asarray(out.valid).any()


@pytest.mark.timeout_guard(300)
def test_concurrent_submit_flush_stress(store):
    """N threads submitting and flushing against one service: no deadlock,
    every ticket resolves, and the stats ledger balances —
    hits + misses == compile-cache lookups == executions issued, and
    executions + coalesced == requests served."""
    service = PredictionService(store)
    queries = [
        SQL,
        "SELECT pid, age, PREDICT(MODEL='los_pi') AS los "
        "FROM patient_info WHERE age > 45",
        "SELECT pid, PREDICT(MODEL='los_pi') AS los FROM patient_info",
    ]
    n_threads, per_thread = 8, 6
    before_compiles = codegen.compile_stats["plans_compiled"]
    results, errors = {}, []
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        try:
            barrier.wait(timeout=30)
            for i in range(per_thread):
                ticket = service.submit(queries[(tid + i) % len(queries)])
                service.flush()
                results[(tid, i)] = ticket.result(timeout=60.0)
        except Exception as e:            # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "worker deadlocked"
    assert not errors
    assert len(results) == n_threads * per_thread
    for out in results.values():
        assert np.asarray(out.valid).any()

    s = service.stats
    # every group serve performs exactly one cache lookup and one execution
    assert s.cache_hits + s.cache_misses == s.batch_executions
    assert s.batch_executions + s.coalesced_requests \
        == n_threads * per_thread
    # every plan compile is accounted for: one per miss, plus any splice
    # upgrades / rematerializations (none expected for disjoint prefixes)
    assert codegen.compile_stats["plans_compiled"] - before_compiles \
        == s.cache_misses + s.splice_upgrades + s.rematerializations
    assert s.rematerializations == 0
