"""SQL front-door diagnostics: every failure is a *positioned* SqlError.

The contract under test (satellite of the multi-tenant front door): any
malformed, truncated or mutated query string surfaces as
:class:`~repro.core.sql_frontend.SqlError` carrying

- ``pos`` — an integer character offset into the original text,
  ``0 <= pos <= len(sql)``;
- a caret snippet in ``str(err)`` whose ``^`` aligns with that offset;

never a raw ``IndexError``/``StopIteration``/``AttributeError`` escaping
the parser.  Unknown tables/columns/models (resolved against the catalog)
raise :class:`SqlLookupError`, which is *also* a ``KeyError`` — the
pre-front-door contract for catalog lookups.

Without a ``hypothesis`` dependency the property is checked by exhaustive
truncation plus seeded random mutation — deterministic across runs.
"""

import random
import string

import numpy as np
import pytest

from repro.core import ModelStore
from repro.core.sql_frontend import SqlError, SqlLookupError, parse_query
from repro.data import hospital_tables
from repro.ml import DecisionTree, Pipeline, PipelineMetadata, StandardScaler

pytestmark = pytest.mark.tier1

FEATS = ["age", "gender", "pregnant", "rcount"]

VALID_QUERIES = [
    "SELECT pid, age FROM patient_info WHERE age > 30",
    ("SELECT pid, PREDICT(MODEL='m') AS p FROM patient_info "
     "WHERE age > 30 AND PREDICT(MODEL='m') > 5"),
    ("SELECT gender, AVG(length_of_stay) AS alos FROM patient_info "
     "GROUP BY gender ORDER BY alos DESC LIMIT 3"),
    "SELECT pid FROM patient_info WHERE age > :lo AND age < :hi",
    "SELECT pid, age FROM patient_info WHERE age > ? ORDER BY age LIMIT 5",
]


@pytest.fixture(scope="module")
def store():
    store = ModelStore()
    for n, t in hospital_tables(200, seed=7).items():
        store.register_table(n, t)
    pi = store.get_table("patient_info")
    data = {c: np.asarray(pi.column(c)) for c in pi.names}
    sc = StandardScaler(FEATS).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=4),
                    PipelineMetadata(name="m", task="regression"))
    pipe.fit({k: data[k] for k in FEATS}, data["length_of_stay"])
    store.register_model("m", pipe)
    return store


def _assert_positioned(err: SqlError, sql: str):
    assert isinstance(err, SqlError)
    assert isinstance(err.pos, int), f"no position on: {err.message}"
    assert 0 <= err.pos <= len(sql)
    rendered = str(err)
    assert f"(at offset {err.pos})" in rendered
    lines = rendered.splitlines()
    if err.sql is not None:
        # caret line aligns under the snippet line
        assert lines[-1].strip() == "^"


# ---------------------------------------------------------------------------
# Directed cases: the offset points at the offending token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sql, fragment", [
    ("SELECT FROM patient_info", "FROM"),
    ("SELECT pid patient_info", "patient_info"),
    ("SELECT pid FROM", None),                     # end of query
    ("SELECT pid FROM patient_info WHERE", None),
    ("SELECT pid FROM patient_info WHERE age >", None),
    ("SELECT pid FROM patient_info WHERE age > 'x", "'x"),
    ("SELECT pid FROM patient_info GROUP BY", None),
    ("SELECT pid, PREDICT(MODEL=) AS p FROM patient_info", ")"),
    ("SELECT pid, PREDICT(MODEL'm') AS p FROM patient_info", "'m'"),
    ("SELECT pid, PREDICT() AS p FROM patient_info", ")"),
    ("SELECT pid FROM patient_info WHERE age > 30 !", "!"),
])
def test_offset_points_at_offending_token(store, sql, fragment):
    with pytest.raises(SqlError) as exc:
        parse_query(sql, store)
    _assert_positioned(exc.value, sql)
    if fragment is None:
        assert exc.value.pos == len(sql)
    else:
        assert exc.value.pos == sql.index(fragment)


@pytest.mark.parametrize("sql, name, kind", [
    ("SELECT pid FROM no_such_table", "no_such_table", "table"),
    ("SELECT zzz FROM patient_info", "zzz", "column"),
    ("SELECT pid FROM patient_info WHERE bogus > 1", "bogus", "column"),
    ("SELECT pid FROM patient_info ORDER BY nope", "nope", "column"),
    # model-name errors point at the string *token* (opening quote)
    ("SELECT pid, PREDICT(MODEL='ghost') AS p FROM patient_info",
     "'ghost'", "model"),
])
def test_unknown_names_are_lookup_errors(store, sql, name, kind):
    with pytest.raises(SqlLookupError) as exc:
        parse_query(sql, store)
    _assert_positioned(exc.value, sql)
    assert f"unknown {kind}" in exc.value.message
    assert exc.value.pos == sql.index(name)
    # backward compat: catalog misses were KeyErrors before positioning
    assert isinstance(exc.value, KeyError)


def test_caret_alignment_renders_under_offset(store):
    sql = "SELECT pid FROM patient_info WHERE bogus > 1"
    with pytest.raises(SqlError) as exc:
        parse_query(sql, store)
    rendered = str(exc.value).splitlines()
    snippet, caret = rendered[-2], rendered[-1]
    # both lines share the same indent, so the caret's string index lands
    # exactly on the offending character in the snippet line
    assert snippet[caret.index("^"):].startswith("bogus")


def test_mixed_param_styles_rejected(store):
    sql = "SELECT pid FROM patient_info WHERE age > ? AND age < :hi"
    with pytest.raises(SqlError) as exc:
        parse_query(sql, store)
    _assert_positioned(exc.value, sql)
    assert "mix" in exc.value.message


# ---------------------------------------------------------------------------
# Property: truncation and mutation never escape SqlError
# ---------------------------------------------------------------------------

def test_every_truncation_fails_positioned_or_parses(store):
    for sql in VALID_QUERIES:
        for cut in range(len(sql)):
            trunc = sql[:cut]
            try:
                parse_query(trunc, store)
            except SqlError as err:
                _assert_positioned(err, trunc)
            # no other exception type may escape


def test_seeded_mutations_fail_positioned_or_parse(store):
    rng = random.Random(0xC0FFEE)
    alphabet = string.ascii_letters + string.digits + " '()<>=*,.?:!@#$%"
    checked = failures = 0
    for sql in VALID_QUERIES:
        for _ in range(200):
            s = list(sql)
            for _ in range(rng.randint(1, 3)):
                op = rng.randrange(3)
                i = rng.randrange(len(s)) if s else 0
                if op == 0 and s:
                    s[i] = rng.choice(alphabet)         # substitute
                elif op == 1 and s:
                    del s[i]                            # delete
                else:
                    s.insert(i, rng.choice(alphabet))   # insert
            mutated = "".join(s)
            checked += 1
            try:
                parse_query(mutated, store)
            except SqlError as err:
                failures += 1
                _assert_positioned(err, mutated)
    assert checked == 1000
    assert failures > 300, "mutation corpus too tame to mean anything"


def test_random_garbage_fails_positioned(store):
    rng = random.Random(7)
    printable = string.printable
    for _ in range(300):
        garbage = "".join(rng.choice(printable)
                          for _ in range(rng.randint(0, 60)))
        try:
            parse_query(garbage, store)
        except SqlError as err:
            _assert_positioned(err, garbage)


# ---------------------------------------------------------------------------
# Catalogs without schema skip name resolution (old contract)
# ---------------------------------------------------------------------------

class _ModelsOnly:
    def get_model(self, name):
        raise KeyError(name)


def test_schemaless_catalog_skips_column_resolution():
    plan = parse_query("SELECT anything FROM wherever WHERE x > 1",
                       _ModelsOnly())
    assert plan.output is not None


def test_schemaless_catalog_still_positions_model_errors():
    sql = "SELECT pid, PREDICT(MODEL='nope') AS p FROM t"
    with pytest.raises(SqlLookupError) as exc:
        parse_query(sql, _ModelsOnly())
    assert exc.value.pos == sql.index("'nope'")
