"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import wkv6_scan_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.tree_gemm.ops import tree_gemm
from repro.kernels.tree_gemm.ref import tree_gemm_ref
from repro.ml import RandomForest, ensemble_to_gemm, predict_ensemble_gemm


@pytest.mark.parametrize("b,s,h,kv,d,dtype", [
    (1, 128, 4, 2, 64, jnp.float32),
    (2, 192, 4, 4, 64, jnp.float32),
    (1, 128, 8, 2, 128, jnp.float32),
    (2, 256, 2, 1, 64, jnp.bfloat16),
])
@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 0, 30.0), (False, 0, 0.0),
])
def test_flash_attention_sweep(b, s, h, kv, d, dtype, causal, window, cap):
    key = jax.random.PRNGKey(b * 100 + s)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=cap, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("b,t,h,kv,d", [
    (2, 256, 8, 2, 64), (1, 300, 4, 4, 128), (3, 128, 8, 1, 64),
])
def test_decode_attention_sweep(b, t, h, kv, d):
    key = jax.random.PRNGKey(t)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, t, kv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, t, kv, d), jnp.float32)
    lens = jax.random.randint(ks[3], (b,), 1, t + 1)
    out = decode_attention(q, kc, vc, lens, block_k=128)
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("b,s,h,kk,chunk", [
    (1, 32, 2, 64, 16), (2, 48, 4, 64, 16), (1, 40, 1, 64, 8),
])
def test_rwkv6_scan_sweep(b, s, h, kk, chunk):
    key = jax.random.PRNGKey(s)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, kk)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, kk)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, kk)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, kk))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, kk)) * 0.1
    out = rwkv6_scan(r, k, v, w, u, chunk=chunk)
    ref = wkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4)


def test_rwkv6_strong_decay_stable():
    """Strong decays underflow but never overflow/NaN (the numerics that
    forced the pairwise-chunk formulation)."""
    b, s, h, kk = 1, 32, 2, 64
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (b, s, h, kk))
    k = jax.random.normal(ks[1], (b, s, h, kk))
    v = jax.random.normal(ks[2], (b, s, h, kk))
    w = jnp.full((b, s, h, kk), 1e-6)      # near-total decay
    u = jnp.zeros((h, kk))
    out = rwkv6_scan(r, k, v, w, u, chunk=16)
    ref = wkv6_scan_ref(r, k, v, w, u)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 32, 2, 8, 4, 16), (2, 64, 3, 16, 8, 16), (1, 48, 2, 8, 4, 8),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    key = jax.random.PRNGKey(s + p)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    out = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    ref = ssd_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4)


@pytest.mark.parametrize("n_trees,depth,n", [(3, 4, 200), (8, 5, 137)])
def test_tree_gemm_kernel_vs_forest(n_trees, depth, n):
    rng = np.random.default_rng(depth)
    x = rng.normal(size=(500, 6)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.int32)
    rf = RandomForest(n_trees=n_trees, max_depth=depth).fit(x, y)
    ens = ensemble_to_gemm(rf.trees, pad_to=128)
    xs = jnp.asarray(x[:n])
    got = np.asarray(tree_gemm(ens, xs))
    ref = np.asarray(predict_ensemble_gemm(ens, xs))
    np.testing.assert_allclose(got, ref, atol=1e-5)
    raw = np.asarray(tree_gemm_ref(
        xs, jnp.asarray(ens.a), jnp.asarray(ens.b), jnp.asarray(ens.c),
        jnp.asarray(ens.d), jnp.asarray(ens.e))) / ens.n_trees
    np.testing.assert_allclose(raw, ref, atol=1e-5)
