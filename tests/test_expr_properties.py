"""Property tests: expression constant folding preserves semantics, and
constraint extraction is sound (never claims a constraint the data can
violate)."""

import os

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.relational.expr import (BinOp, CaseWhen, Col, Const, UnaryOp,
                                   conjuncts, extract_constraints,
                                   fold_constants)

settings.register_profile("ci2", max_examples=40, deadline=None)
settings.load_profile(
    os.environ.get("HYPOTHESIS_PROFILE", "ci2"))

_NUM = st.floats(-10, 10, allow_nan=False, width=32)


@st.composite
def exprs(draw, depth=0):
    """Random expression over columns a (float) and b (int)."""
    if depth > 3 or draw(st.booleans()):
        return draw(st.sampled_from([
            Col("a"), Col("b"), Const(draw(_NUM)),
            Const(draw(st.integers(-5, 5)))]))
    op = draw(st.sampled_from(["+", "-", "*", "<", "<=", ">", ">=", "==",
                               "and", "or"]))
    left = draw(exprs(depth=depth + 1))
    right = draw(exprs(depth=depth + 1))
    if op in ("and", "or"):
        # boolean operands: wrap numerics in comparisons
        left = BinOp("<", left, Const(draw(_NUM)))
        right = BinOp(">", right, Const(draw(_NUM)))
    return BinOp(op, left, right)


@given(exprs(), st.lists(_NUM, min_size=3, max_size=8))
def test_fold_constants_preserves_value(expr, vals):
    cols = {"a": jnp.asarray(vals, jnp.float32),
            "b": jnp.asarray(np.arange(len(vals)), jnp.int32)}
    before = np.asarray(expr.evaluate(cols))
    after = np.asarray(fold_constants(expr).evaluate(cols))
    if before.dtype.kind == "b":
        np.testing.assert_array_equal(before, after)
    else:
        np.testing.assert_allclose(before, after, rtol=1e-5, atol=1e-5)


@given(st.lists(_NUM, min_size=5, max_size=20),
       st.floats(-5, 5, allow_nan=False),
       st.floats(-5, 5, allow_nan=False))
def test_extract_constraints_sound(vals, lo, hi):
    """Rows passing the predicate must satisfy every extracted constraint."""
    pred = BinOp("and", BinOp(">", Col("a"), Const(lo)),
                 BinOp("<=", Col("a"), Const(hi)))
    cols = {"a": jnp.asarray(vals, jnp.float32)}
    mask = np.asarray(pred.evaluate(cols))
    cons = extract_constraints(pred)
    arr = np.asarray(vals, np.float32)
    for c in cons:
        passing = arr[mask]
        if c.kind == ">":
            assert (passing > c.value).all()
        elif c.kind == "<=":
            assert (passing <= c.value).all()


def test_case_when_dead_branch_elimination():
    e = CaseWhen(((Const(False), Const(1.0)),
                  (Const(True), Const(2.0)),
                  (BinOp(">", Col("a"), Const(0)), Const(3.0))),
                 Const(4.0))
    folded = fold_constants(e)
    # first branch dead, second always fires -> constant 2.0
    assert isinstance(folded, Const) and folded.value == 2.0


def test_conjuncts_flatten():
    e = BinOp("and", BinOp("and", Col("a") > 1, Col("a") < 5), Col("b") == 2)
    assert len(conjuncts(e)) == 3
