"""Stats-ledger audits (ISSUE 9): the counters the metrics registry
exports must balance against each other — an accounting identity per
serving tier, checked under multi-threaded stress so lost/double counts
under lock contention cannot hide:

- signature tier:  cache_hits + cache_misses == batch_executions
                   (every group serve does exactly one signature lookup
                   and issues exactly one execution);
- admission tier:  batch_executions + coalesced_requests == submitted
                   (every admitted request is either the head of an
                   execution or coalesced into one);
- bucket tier:     bucket_hits + bucket_compiles == batch_executions
                   when every execution is stacked (one shape-bucket
                   lookup per stacked execution);
- shedding tier:   submitted + deadline_rejections + queue_rejections
                   == attempts, and shed requests never execute.

Plus the per-tenant queue-wait EWMA regression (ManualClock): a flooded
tenant's backlog must shed *its own* requests without inflating a
compliant neighbor's estimate — the neighbor's calibrated EWMA wins over
the polluted global one.
"""

import threading

import numpy as np
import pytest

from repro.core import ModelStore
from repro.data import hospital_tables
from repro.ml import DecisionTree, Pipeline, PipelineMetadata, StandardScaler
from repro.relational.table import Table
from repro.serve import (AdmissionConfig, DeadlineUnmeetable, ManualClock,
                         PredictionService)

pytestmark = pytest.mark.tier1

N_ROWS = 400
FEATS = ["age", "gender", "pregnant", "rcount"]
SQL = "SELECT pid, PREDICT(MODEL='m') AS p FROM patient_info WHERE age > 30"
QUERIES = [
    SQL,
    "SELECT pid, age, PREDICT(MODEL='m') AS p FROM patient_info "
    "WHERE age > 45",
    "SELECT pid FROM patient_info WHERE age > 60",
]


@pytest.fixture(scope="module")
def base():
    full = hospital_tables(N_ROWS, seed=7)["patient_info"]
    data = {c: np.asarray(full.column(c)) for c in full.names}
    sc = StandardScaler(FEATS).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=5),
                    PipelineMetadata(name="m", task="regression"))
    pipe.fit({k: data[k] for k in FEATS}, data["length_of_stay"])
    store = ModelStore()
    store.register_table("patient_info", full)
    store.register_model("m", pipe)
    return store, full


def _sub(full: Table, lo: int, n: int) -> Table:
    return Table({k: v[lo:lo + n] for k, v in full.columns.items()},
                 full.valid[lo:lo + n], full.schema)


def _stress(service, submit_one, n_threads=8, per_thread=6):
    """N threads x per_thread submit+flush rounds; returns the resolved
    outputs, asserting no deadlock and no worker error."""
    results, errors = {}, []
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        try:
            barrier.wait(timeout=30)
            for i in range(per_thread):
                ticket = submit_one(tid, i)
                service.flush()
                results[(tid, i)] = ticket.result(timeout=60.0)
        except Exception as e:            # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "worker deadlocked"
    assert not errors, errors
    assert len(results) == n_threads * per_thread
    return results


@pytest.mark.timeout_guard(300)
def test_ledger_balances_under_catalog_stress(base):
    """Identical-catalog requests (the coalescing path): signature and
    admission tiers balance exactly, and the registry snapshot agrees
    with the raw stats it collects from."""
    store, _ = base
    service = PredictionService(store)
    n_threads, per_thread = 8, 6
    results = _stress(
        service,
        lambda tid, i: service.submit(QUERIES[(tid + i) % len(QUERIES)]),
        n_threads, per_thread)
    for out in results.values():
        assert np.asarray(out.valid).any()

    s = service.stats
    assert s.cache_hits + s.cache_misses == s.batch_executions
    assert s.batch_executions + s.coalesced_requests \
        == n_threads * per_thread == s.submitted
    assert s.queue_rejections == 0 and s.deadline_rejections == 0
    # the registry is a view, not a second ledger: collected counters
    # must equal the stats they sample
    snap = service.metrics_snapshot()
    assert snap["counters"]["repro_submitted_total"] == s.submitted
    assert snap["counters"]["repro_batch_executions_total"] \
        == s.batch_executions
    assert snap["counters"]["repro_coalesced_requests_total"] \
        == s.coalesced_requests
    info = service.admission_info()
    assert info["queue_depth_high_water"] >= 1
    service.close()


@pytest.mark.timeout_guard(300)
def test_bucket_ledger_balances_under_override_stress(base):
    """All-override requests (the stacked path): every execution performs
    exactly one shape-bucket lookup — bucket_hits + bucket_compiles must
    equal batch_executions, with row counts spanning several buckets."""
    store, full = base
    service = PredictionService(store, admission=AdmissionConfig(
        min_bucket_rows=8))
    sizes = [3, 8, 9, 17, 30, 33]
    n_threads, per_thread = 8, 6
    results = _stress(
        service,
        lambda tid, i: service.submit(
            SQL, {"patient_info": _sub(full, 0, sizes[(tid + i)
                                                     % len(sizes)])}),
        n_threads, per_thread)
    for (tid, i), out in results.items():
        assert out.capacity == sizes[(tid + i) % len(sizes)]

    s = service.stats
    assert s.batch_executions > 0
    assert s.bucket_hits + s.bucket_compiles == s.batch_executions
    assert s.batch_executions + s.coalesced_requests \
        == n_threads * per_thread == s.submitted
    service.close()


def test_shed_ledger_balances_on_manual_clock(base):
    """Deterministic shedding audit: every attempt is admitted, coalesced
    into an execution, or shed — and shed requests never execute."""
    store, _ = base
    clock = ManualClock()
    service = PredictionService(store, clock=clock,
                                admission=AdmissionConfig(
                                    latency_budget_s=1.0, background=False))
    # calibrate: one served request seeds the queue-wait and exec EWMAs
    t0 = service.submit(SQL)
    clock.advance(2.0)
    assert service.admission_tick() == 1
    t0.result(timeout=0)
    est = service._deadline_estimate(
        service._cache_key(service._to_plan(SQL), None)[0])
    assert est is not None and est >= 2.0 * 0.9

    attempts, shed = 0, 0
    for deadline in (0.01, 100.0, 0.5, 100.0):
        attempts += 1
        try:
            t = service.submit(SQL, deadline_s=deadline)
        except DeadlineUnmeetable:
            shed += 1
            continue
        clock.advance(1.5)
        service.admission_tick()
        t.result(timeout=0)

    s = service.stats
    assert shed == 2 == s.deadline_rejections
    assert s.submitted == attempts - shed + 1          # +1: the calibrator
    assert s.batch_executions + s.coalesced_requests == s.submitted
    assert s.batch_executions + s.coalesced_requests \
        + s.deadline_rejections + s.queue_rejections == attempts + 1
    # the shed requests' traces carry the decision with both numbers
    shed_traces = [t for t in service.traces()
                   if t.find("deadline_shed") is not None]
    assert len(shed_traces) == 2
    ev = shed_traces[0].find("deadline_shed")
    assert ev.attrs["estimate"] > ev.attrs["deadline"]
    service.close()


def test_per_tenant_ewma_isolates_shedding(base):
    """Regression (ISSUE 9 satellite): _deadline_estimate must prefer the
    tenant's own calibrated queue-wait EWMA.  Tenant A's 5s backlog and
    tenant B's 0.1s waits pollute the *global* EWMA to ~4s; a 1s-deadline
    request from B must still be admitted (its own estimate ~0.1s) while
    the same request from A sheds — and before this mechanism existed, B
    would have been shed on the fleet average."""
    store, _ = base
    clock = ManualClock()
    service = PredictionService(store, clock=clock,
                                admission=AdmissionConfig(
                                    latency_budget_s=1.0, background=False))
    # tenant A: one slow round calibrates its EWMA at 5.0s
    ta = service.submit(SQL, tenant="A")
    clock.advance(5.0)
    service.admission_tick()
    ta.result(timeout=0)
    # tenant B: one fast round calibrates its EWMA at 0.1s; the global
    # EWMA is now 5.0 + 0.2*(0.1-5.0) = 4.02s — useless for B
    tb = service.submit(SQL, tenant="B")
    clock.advance(0.1)
    service.flush()                    # inside the budget: drain explicitly
    tb.result(timeout=0)

    key = service._cache_key(service._to_plan(SQL), None)[0]
    est_a = service._deadline_estimate(key, "A")
    est_b = service._deadline_estimate(key, "B")
    est_global = service._deadline_estimate(key)
    assert est_a == pytest.approx(5.0, rel=0.05)
    assert est_b == pytest.approx(0.1, rel=0.5)
    assert est_global == pytest.approx(4.02, rel=0.05)

    # B's 1s deadline is fine on its own estimate (the global would shed)
    tb2 = service.submit(SQL, tenant="B", deadline_s=1.0)
    clock.advance(1.5)
    service.admission_tick()
    assert tb2.result(timeout=0) is not None
    # the same deadline from flooded A sheds on A's own estimate
    with pytest.raises(DeadlineUnmeetable):
        service.submit(SQL, tenant="A", deadline_s=1.0)
    # an uncalibrated tenant falls back to the (polluted) global estimate
    with pytest.raises(DeadlineUnmeetable):
        service.submit(SQL, tenant="C", deadline_s=1.0)

    tinfo = service.tenant_info()
    assert tinfo["A"]["deadline_rejections"] == 1
    assert tinfo["B"]["deadline_rejections"] == 0
    # the per-tenant EWMA gauge is exported for exactly A and B
    text = service.metrics_text()
    assert 'repro_tenant_queue_wait_ewma_seconds{tenant="A"} 5' in text
    assert 'repro_tenant_queue_wait_ewma_seconds{tenant="B"}' in text
    service.close()
