"""SQL parser + Python static analyzer tests."""

import numpy as np
import pytest

from repro.core import analyze_script, execute, parse_query
from repro.core.sql_frontend import SqlError


def test_parse_basic_projection(hospital_tree):
    store, data, _ = hospital_tree
    plan = parse_query("SELECT pid, age FROM patient_info WHERE age > 50",
                       store)
    out = execute(plan, store).to_pydict()
    assert all(a > 50 for a in out["age"])
    assert set(out) == {"pid", "age"}


def test_parse_aggregates(hospital_tree):
    store, data, _ = hospital_tree
    plan = parse_query(
        "SELECT COUNT(*) AS n, AVG(age) AS mean_age FROM patient_info",
        store)
    out = execute(plan, store).to_pydict()
    assert out["n"] == [len(data["age"])]
    assert abs(out["mean_age"][0] - data["age"].mean()) < 0.1


def test_parse_group_by(hospital_tree):
    store, data, _ = hospital_tree
    plan = parse_query(
        "SELECT gender, COUNT(*) AS n FROM patient_info GROUP BY gender",
        store)
    out = execute(plan, store).to_pydict()
    assert sum(out["n"]) == len(data["gender"])


def test_parse_order_limit(hospital_tree):
    store, data, _ = hospital_tree
    plan = parse_query(
        "SELECT pid, age FROM patient_info ORDER BY age DESC LIMIT 5",
        store)
    out = execute(plan, store).to_pydict()
    assert len(out["age"]) == 5
    assert sorted(out["age"], reverse=True) == \
        sorted(data["age"].tolist(), reverse=True)[:5]


def test_parse_between_and_case(hospital_tree):
    store, data, _ = hospital_tree
    plan = parse_query(
        "SELECT pid, CASE WHEN age BETWEEN 30 AND 40 THEN 1 ELSE 0 END "
        "AS mid FROM patient_info", store)
    out = execute(plan, store).to_pydict()
    ref = ((data["age"] >= 30) & (data["age"] <= 40)).astype(float)
    assert np.allclose(out["mid"], ref.tolist())


def test_predict_in_where_and_select_shares_node(hospital_tree):
    store, _, _ = hospital_tree
    plan = parse_query(
        "SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
        "JOIN blood_tests ON pid WHERE PREDICT(MODEL='los') > 5", store)
    predicts = [n for n in plan.nodes.values() if n.op == "predict_model"]
    assert len(predicts) == 1      # deduplicated invocation


def test_parse_errors():
    class Empty:
        def get_model(self, name):
            raise KeyError(name)
    with pytest.raises(SqlError):
        parse_query("SELECT FROM x", Empty())
    with pytest.raises(SqlError):
        parse_query("SELECT a FROM t WHERE", Empty())


# -- static analyzer ---------------------------------------------------------

def test_analyze_script_full_pipeline(hospital_tree):
    store, data, pipe = hospital_tree
    src = """
df = load_table('patient_info')
bt = load_table('blood_tests')
df = df.merge(bt, on='pid')
df = df[(df['pregnant'] == 1) & (df['age'] > 25)]
pred = model.predict(df)
df['los'] = pred
df = df[df['los'] > 5]
"""
    plan, n_udf = analyze_script(src, store, objects={"model": pipe})
    assert n_udf == 0
    out = execute(plan, store).to_pydict()
    assert len(out["pid"]) > 0
    assert all(v > 5 for v in out["los"])
    # cross-check against the SQL route
    sql_plan = parse_query(
        "SELECT * FROM patient_info JOIN blood_tests ON pid "
        "WHERE pregnant = 1 AND age > 25 AND PREDICT(MODEL='los') > 5",
        store)
    sql_out = execute(sql_plan, store).to_pydict()
    assert sorted(sql_out["pid"]) == sorted(out["pid"])


def test_analyze_script_attribute_access(hospital_tree):
    store, data, pipe = hospital_tree
    src = """
df = load_table('patient_info')
df = df[df.age > 60]
"""
    plan, n_udf = analyze_script(src, store)
    out = execute(plan, store).to_pydict()
    assert all(a > 60 for a in out["age"])


def test_analyze_script_loop_falls_back_to_udf(hospital_tree):
    store, _, pipe = hospital_tree
    src = """
df = load_table('patient_info')
for i in range(3):
    df = df
"""
    plan, n_udf = analyze_script(src, store)
    assert n_udf == 1      # the loop became an opaque UDF (paper §3.2)


def test_analyze_script_computed_column(hospital_tree):
    store, data, _ = hospital_tree
    src = """
df = load_table('patient_info')
df['age2'] = df['age'] * 2 + 1
"""
    plan, _ = analyze_script(src, store)
    out = execute(plan, store).to_pydict()
    assert np.allclose(out["age2"], (data["age"] * 2 + 1).tolist())
