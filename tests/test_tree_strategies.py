"""Tree-inference strategy equivalence and crossover sanity (Fig 2d).

The plan path may serve a tree ensemble three ways — native traversal,
the gather-gated dense GEMM lowering, or the Pallas MXU kernel — chosen
by a *measured* cost-model crossover.  The strategies must be freely
interchangeable, which here means **bitwise identical** predictions:

- gather gating ``x[:, feat[t]] <= b[t]`` reproduces traversal's exact
  per-node comparisons (NaN compares False -> right child, same as
  traversal);
- path-count sums are exact small integers (products of {-1, 0, +1}),
  so the ``S == D`` match is reduction-order independent;
- per-tree accumulation is sequential (``fori_loop``), matching
  ``predict_scores``'s left-to-right sum, and padding contributes exact
  zeros.

The property test drives random forests, feature dtypes and NaN/±inf
features through all three strategies; the crossover test checks the
estimator never picks a strategy that measures much slower than its
runner-up on the calibration workload.
"""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.cost_model import (calibrated_tree_costs,
                                   choose_tree_strategy,
                                   tree_strategy_costs)
from repro.core.model_store import ModelStore
from repro.kernels.tree_gemm import ops as tg_ops
from repro.ml import RandomForest, ensemble_to_gemm, predict_ensemble_gemm
from repro.ml.hummingbird import ensemble_to_gemm_mxu

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("tree_strategies", max_examples=12,
                              deadline=None)
    settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "tree_strategies"))
    HAVE_HYPOTHESIS = True
except ImportError:                     # property test degrades to the
    HAVE_HYPOTHESIS = False             # deterministic grid below


def _forest_and_x(seed, n_trees, depth, n_features, n_rows, dtype_kind,
                  nan_frac):
    rng = np.random.default_rng(seed)
    if dtype_kind == "int":
        xf = rng.integers(-8, 8, size=(256, n_features)).astype(np.float32)
    else:
        xf = rng.normal(size=(256, n_features)).astype(np.float32)
    y = (xf[:, 0] > xf[:, -1]).astype(np.int32)
    rf = RandomForest(n_trees=n_trees, max_depth=depth, min_leaf=2,
                      seed=seed).fit(xf, y)
    if dtype_kind == "int":
        x = rng.integers(-10, 10, size=(n_rows, n_features)) \
            .astype(np.float32)
    else:
        x = rng.normal(size=(n_rows, n_features)).astype(np.float32)
    if nan_frac:
        mask = rng.random(x.shape) < nan_frac
        x[mask] = np.nan
        x[rng.random(x.shape) < nan_frac / 2] = np.inf
        x[rng.random(x.shape) < nan_frac / 2] = -np.inf
    return rf, x


def _assert_bitwise(seed, n_trees, depth, n_features, dtype_kind, nan_frac):
    rf, x = _forest_and_x(seed, n_trees, depth, n_features, n_rows=48,
                          dtype_kind=dtype_kind, nan_frac=nan_frac)
    xj = jnp.asarray(x)
    # All strategies jitted, as the plan path runs them: XLA rewrites the
    # final divide-by-n_trees into multiply-by-reciprocal, so an eager
    # reference would differ by 1 ulp whenever n_trees isn't a power of 2.
    want = np.asarray(jax.jit(rf.predict_scores)(xj))

    ens8 = ensemble_to_gemm(rf.trees, pad_to=8)
    ens128 = ensemble_to_gemm_mxu(rf.trees)
    dense = np.asarray(jax.jit(
        lambda v: predict_ensemble_gemm(ens8, v))(xj))
    mxu = np.asarray(jax.jit(
        lambda v: predict_ensemble_gemm(ens128, v))(xj))
    pallas = np.asarray(tg_ops.tree_gemm(ens128, xj, interpret=True))

    np.testing.assert_array_equal(want, dense)
    np.testing.assert_array_equal(want, mxu)
    np.testing.assert_array_equal(want, pallas)


_GRID = [  # (seed, n_trees, depth, n_features, dtype_kind, nan_frac)
    (0, 1, 2, 2, "float", 0.0),
    (1, 6, 6, 9, "float", 0.0),
    (2, 4, 5, 5, "float", 0.05),
    (3, 3, 4, 3, "float", 0.25),
    (4, 5, 6, 7, "int", 0.0),
    (5, 2, 3, 4, "int", 0.05),
    (6, 6, 4, 8, "int", 0.25),
    (7, 1, 6, 6, "float", 0.25),
]


@pytest.mark.parametrize("case", _GRID, ids=lambda c: f"seed{c[0]}")
def test_traversal_gemm_pallas_bitwise(case):
    """traversal == dense GEMM (any pad) == Pallas(interpret), bitwise,
    including NaN/±inf features (deterministic grid)."""
    _assert_bitwise(*case)


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1),
           n_trees=st.integers(1, 6),
           depth=st.integers(2, 6),
           n_features=st.integers(2, 9),
           dtype_kind=st.sampled_from(["float", "int"]),
           nan_frac=st.sampled_from([0.0, 0.05, 0.25]))
    def test_traversal_gemm_pallas_bitwise_fuzz(seed, n_trees, depth,
                                                n_features, dtype_kind,
                                                nan_frac):
        """Same property, hypothesis-driven when the library is present."""
        _assert_bitwise(seed, n_trees, depth, n_features, dtype_kind,
                        nan_frac)


def test_crossover_not_worse_than_runner_up():
    """On the calibration workload itself, the chosen strategy's *measured*
    time is never more than 2x the measured runner-up — i.e. the estimator
    can mis-rank close calls but not pick a blowout loser."""
    cal = calibrated_tree_costs()
    rng = np.random.default_rng(3)
    xf = rng.normal(size=(512, 8)).astype(np.float32)
    y = (xf[:, 0] + xf[:, 1] > 0).astype(np.int32)
    rf = RandomForest(n_trees=8, max_depth=6).fit(xf, y)
    ens = ensemble_to_gemm(rf.trees, pad_to=8)
    n = 2048
    x = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)

    import time

    def best_of(fn):
        jax.block_until_ready(fn(x))
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            best = min(best, time.perf_counter() - t0)
        return best

    fns = {
        "traversal": jax.jit(rf.predict_scores),
        "gemm": jax.jit(lambda v: predict_ensemble_gemm(ens, v)),
    }
    chosen, costs = choose_tree_strategy(rf, n, 8)
    if chosen == "pallas":              # only chosen on a real TPU
        fns["pallas"] = lambda v: tg_ops.tree_gemm(ens, v, interpret=False)
    # a single noisy sample (GC pause, CI neighbor) shouldn't fail the
    # build: re-measure up to 3 times and accept any clean round
    for attempt in range(3):
        measured = {k: best_of(fn) for k, fn in fns.items()}
        runner_up = min((k for k in measured if k != chosen),
                        key=measured.get)
        if measured[chosen] <= 2.0 * measured[runner_up]:
            break
    else:
        raise AssertionError((chosen, measured, costs))
    # and the estimator's own ranking agrees with itself: chosen is either
    # the outright cheapest, or traversal retained because no translated
    # strategy beat it by more than the calibration-noise margin
    from repro.core.cost_model import _STRATEGY_MARGIN
    if chosen == "traversal":
        assert min(costs.values()) > _STRATEGY_MARGIN * costs["traversal"]
    else:
        assert costs[chosen] == min(costs.values())
        assert costs[chosen] <= _STRATEGY_MARGIN * costs["traversal"]


def test_strategy_costs_monotone_in_rows():
    """Estimated cost is monotone non-decreasing in n_rows for every
    strategy, and traversal wins tiny batches (its per-call setup is the
    smallest term)."""
    cal = calibrated_tree_costs()
    rng = np.random.default_rng(5)
    xf = rng.normal(size=(256, 8)).astype(np.float32)
    rf = RandomForest(n_trees=8, max_depth=6).fit(
        xf, (xf[:, 0] > 0).astype(np.int32))
    prev = None
    for n in (1, 32, 1024, 32768, 1 << 20):
        costs = tree_strategy_costs(rf, n, 8, cal)
        if prev is not None:
            for k in ("traversal", "gemm"):
                assert costs[k] >= prev[k]
        prev = costs


def test_calibration_cached_in_model_store():
    """calibrated_tree_costs measures once and caches in the catalog, so a
    fresh optimizer run against the same ModelStore never re-times."""
    store = ModelStore()
    cal1 = calibrated_tree_costs(catalog=store)
    assert store.get_calibration(("tree_strategy", cal1.backend)) is cal1
    cal2 = calibrated_tree_costs(catalog=store)
    assert cal2 is cal1
    assert cal1.trav_step > 0 and cal1.gemm_flop > 0
    if cal1.backend != "tpu":
        assert cal1.pallas_flop is None
