"""Cross-query materialized sub-plan result cache (tentpole of ISSUE 2).

Two different queries sharing a deterministic ``featurize -> predict``
prefix over the same catalog table: the first query's execution captures
the subtree's materialized value; the second query splices it in as a
``materialized`` leaf and executes only its residual plan.  Guarantees
under test: splicing is bit-exact vs uncached execution, never fires for
caller-supplied tables, survives result eviction via re-materialization,
keys on table registration versions, and the subtree-signature machinery
is self-consistent (incl. the structural-CSE upgrade to subplan_dedup).
"""

import copy

import numpy as np
import pytest

from repro.core import CrossOptimizer, ModelStore, parse_query
from repro.core.ir import (Category, Node, Plan, plan_signature,
                           subtree_signatures)
from repro.data import hospital_tables
from repro.ml import DecisionTree, Pipeline, PipelineMetadata, StandardScaler
from repro.relational.table import Table
from repro.serve import PredictionService

pytestmark = pytest.mark.tier1

FEATS = ["age", "gender", "pregnant", "rcount"]
SQL_A = "SELECT pid, PREDICT(MODEL='m') AS score FROM patient_info"
SQL_B = "SELECT pid, age, PREDICT(MODEL='m') AS score FROM patient_info"


def _pipeline(data, depth=6):
    sc = StandardScaler(FEATS).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=depth),
                    PipelineMetadata(name="m", task="regression"))
    pipe.fit({k: data[k] for k in FEATS}, data["length_of_stay"])
    return pipe


def _make_store(n_rows=400, seed=7):
    store = ModelStore()
    for n, t in hospital_tables(n_rows, seed=seed).items():
        store.register_table(n, t)
    pi = store.get_table("patient_info")
    data = {c: np.asarray(pi.column(c)) for c in pi.names}
    store.register_model("m", _pipeline(data))
    return store


@pytest.fixture()
def store():
    return _make_store()


# ---------------------------------------------------------------------------
# Splicing
# ---------------------------------------------------------------------------

def test_second_query_splices_and_is_bit_exact(store, assert_tables_equal):
    svc = PredictionService(store)
    svc.run(SQL_A)
    assert svc.stats.result_puts == 1
    out_b = svc.run(SQL_B)
    assert svc.stats.result_hits == 1
    assert svc.stats.spliced_executions == 1

    uncached = PredictionService(store, enable_result_cache=False)
    assert_tables_equal(out_b, uncached.run(SQL_B))


def test_alias_only_difference_still_reuses(store, assert_tables_equal):
    """Output aliases live in rename/project attrs; the capture root sits
    below them, so `... AS score` and `... AS s` share the cached
    inference subtree."""
    svc = PredictionService(store)
    svc.run("SELECT pid, PREDICT(MODEL='m') AS score FROM patient_info")
    out = svc.run("SELECT pid, PREDICT(MODEL='m') AS s FROM patient_info")
    assert svc.stats.result_hits == 1, \
        "alias-only rename difference defeated sub-plan reuse"
    uncached = PredictionService(store, enable_result_cache=False)
    want = uncached.run(
        "SELECT pid, PREDICT(MODEL='m') AS s FROM patient_info")
    assert_tables_equal(out, want)


def test_residual_plan_contains_no_inference_ops(store):
    svc = PredictionService(store)
    svc.run(SQL_A)
    compiled_b = svc.compile(SQL_B)
    assert compiled_b.splice is not None
    residual_ops = {n.op for n in compiled_b.plan.nodes.values()}
    assert "materialized" in residual_ops
    assert not residual_ops & {"featurize", "predict_model", "tree_gemm",
                               "matmul_bias"}, \
        f"inference ops survived splicing: {residual_ops}"


def test_rematerialization_after_result_eviction(store, assert_tables_equal):
    """A spliced executable whose cached value was evicted rebuilds it from
    the retained subtree plan — correctness does not depend on residency."""
    svc = PredictionService(store)
    svc.run(SQL_A)
    out1 = svc.run(SQL_B)                  # spliced, cache resident
    svc._result_cache.evict_if(lambda e: True)
    assert svc.cache_info()["result_entries"] == 0
    out2 = svc.run(SQL_B)                  # spliced, must re-materialize
    assert svc.stats.rematerializations == 1
    assert svc.stats.result_misses == 1
    assert svc.cache_info()["result_entries"] == 1   # repopulated
    assert_tables_equal(out1, out2)


def test_parameterized_query_populates_and_reuses_cache(store):
    """A parameter in the WHERE clause used to poison every enclosing
    subtree (`plan_params` vetoed the candidate), so hot parameterized
    queries never captured.  The frontend now routes param-bearing
    conjuncts above ``attach_column``, leaving the inference prefix
    cacheable; distinct bindings then splice from one entry."""
    svc = PredictionService(store)
    q = ("SELECT pid, PREDICT(MODEL='m') AS s FROM patient_info "
         "WHERE age > :lo")
    out1 = svc.run(q, params={"lo": 40.0})
    assert svc.stats.result_puts == 1
    out2 = svc.run(q, params={"lo": 55.0})   # same signature: warm executable
    # a *different* query sharing the inference prefix splices the value
    # the parameterized query captured
    out3 = svc.run("SELECT pid, age, PREDICT(MODEL='m') AS s "
                   "FROM patient_info WHERE age > :lo", params={"lo": 30.0})
    assert svc.stats.result_hits == 1
    assert svc.stats.spliced_executions == 1
    # bindings behave like the literal queries they stand for
    lit = PredictionService(store, enable_result_cache=False)
    for out, lo in ((out1, 40.0), (out2, 55.0)):
        want = lit.run("SELECT pid, PREDICT(MODEL='m') AS s "
                       f"FROM patient_info WHERE age > {lo}")
        assert out.to_pydict() == want.to_pydict()


def test_structural_limit_param_binds_per_value(store):
    """``LIMIT :n`` binds at plan-build time: each value is its own plan
    signature (documented tradeoff), results are exact, and repeats of a
    value reuse its executable."""
    svc = PredictionService(store)
    q = "SELECT pid FROM patient_info LIMIT :n"
    r10 = svc.run(q, params={"n": 10})
    r20 = svc.run(q, params={"n": 20})
    r10b = svc.run(q, params={"n": 10})
    assert len(r10.to_pydict()["pid"]) == 10
    assert len(r20.to_pydict()["pid"]) == 20
    assert r10b.to_pydict() == r10.to_pydict()
    assert svc.stats.cache_misses == 2      # one signature per LIMIT value
    assert svc.stats.cache_hits == 1


def test_overridden_tables_never_capture_or_splice(store):
    pi = store.get_table("patient_info")
    sub = Table({k: v[:100] for k, v in pi.columns.items()},
                pi.valid[:100], pi.schema)
    svc = PredictionService(store)
    svc.run(SQL_A, {"patient_info": sub})
    assert svc.cache_info()["result_entries"] == 0
    assert svc.stats.result_puts == 0
    compiled = svc.compile(SQL_A, {"patient_info": sub})
    assert compiled.capture is None and compiled.splice is None


def test_chunked_execution_populates_capture(store, assert_tables_equal):
    """Morsel execution assembles the captured subtree value from chunk
    pieces; a later query splices it bit-exactly."""
    chunked = PredictionService(store, chunk_rows=128)    # 400 rows -> 4
    chunked.run(SQL_A)
    assert chunked.stats.chunks_executed > 0
    assert chunked.stats.result_puts == 1
    out_b = chunked.run(SQL_B)
    assert chunked.stats.result_hits == 1
    uncached = PredictionService(store, enable_result_cache=False)
    assert_tables_equal(out_b, uncached.run(SQL_B))


def test_result_key_tracks_table_version(store, assert_tables_equal):
    svc = PredictionService(store)
    svc.run(SQL_A)
    out_b1 = svc.run(SQL_B)
    # re-register with shifted data: version bump + invalidation hook
    pi = store.get_table("patient_info")
    shifted = pi.with_columns(
        {"age": np.asarray(pi.column("age"), np.float32) + 1.0})
    store.register_table("patient_info", shifted)
    out_b2 = svc.run(SQL_B)
    fresh = PredictionService(store, enable_result_cache=False)
    assert_tables_equal(out_b2, fresh.run(SQL_B))
    assert not (np.asarray(out_b1.columns["age"])
                == np.asarray(out_b2.columns["age"])).all()


def test_capture_entry_upgrades_to_splice_when_other_query_produces(store, assert_tables_equal):
    """Consumer-compiled-first ordering: B compiles while the cache is
    empty (capture mode), another query later materializes the shared
    subtree -> B's next warm hit recompiles to its residual once and
    splices from then on.  The producer itself never 'upgrades' onto its
    own capture (zero-compile warm repeats stay zero-compile)."""
    svc = PredictionService(store)
    out_b1 = svc.run(SQL_B)                  # B produces (capture mode)
    assert svc.compile(SQL_B).capture is not None
    assert svc.stats.splice_upgrades == 0    # own value: no upgrade

    svc._result_cache.evict_if(lambda e: True)
    svc.run(SQL_A)                           # A captures + repopulates
    assert svc.stats.result_puts == 2

    out_b2 = svc.run(SQL_B)                  # warm hit -> upgrade -> splice
    assert svc.stats.splice_upgrades == 1
    assert svc.stats.result_hits >= 1
    compiled_b = svc.compile(SQL_B)
    assert compiled_b.splice is not None and compiled_b.capture is None
    assert svc.stats.splice_upgrades == 1    # upgrade happens exactly once
    assert_tables_equal(out_b1, out_b2)


def test_close_and_gc_detach_invalidation_listener(store):
    import gc
    n0 = len(store._invalidation_listeners)
    svc = PredictionService(store)
    assert len(store._invalidation_listeners) == n0 + 1
    svc.close()
    assert len(store._invalidation_listeners) == n0
    svc.close()                              # idempotent

    svc2 = PredictionService(store)
    assert len(store._invalidation_listeners) == n0 + 1
    del svc2
    gc.collect()
    assert len(store._invalidation_listeners) == n0, \
        "garbage-collected service left a dead listener behind"


def test_disabled_result_cache_is_inert(store):
    svc = PredictionService(store, enable_result_cache=False)
    svc.run(SQL_A)
    svc.run(SQL_B)
    assert "result_entries" not in svc.cache_info()
    assert svc.stats.result_puts == 0
    assert svc.stats.spliced_executions == 0
    compiled = svc.compile(SQL_A)
    assert compiled.capture is None and compiled.splice is None


# ---------------------------------------------------------------------------
# Subtree-signature machinery
# ---------------------------------------------------------------------------

def test_subtree_signature_consistent_with_plan_signature(store):
    plan = parse_query(SQL_A, store)
    sigs = subtree_signatures(plan)
    assert sigs[plan.output] == plan_signature(plan)
    # every reachable node is signed
    assert set(sigs) == set(plan.nodes)


def test_shared_prefix_has_equal_subtree_signature(store):
    """The reuse precondition: after optimization, queries A and B carry a
    subtree with the same signature."""
    opt = CrossOptimizer(store)
    pa, _ = opt.optimize(parse_query(SQL_A, store))
    pb, _ = opt.optimize(parse_query(SQL_B, store))
    shared = set(subtree_signatures(pa).values()) \
        & set(subtree_signatures(pb).values())
    assert shared, "no shared subtree between A and B after optimization"


def test_structural_cse_merges_content_identical_models(store):
    """subplan_dedup's structural pass merges two predict chains whose model
    objects are distinct Python objects with identical content — the old
    id()-keyed pass could not."""
    pipe = store.get_model("m")
    clone = copy.deepcopy(pipe)
    plan = Plan()
    scan = plan.emit("scan", Category.RA, [], "table", table="patient_info")
    f1 = plan.emit("featurize", Category.MLD, [scan], "matrix",
                   featurizers=pipe.featurizers, pipeline_name="m",
                   input_columns=tuple(FEATS))
    p1 = plan.emit("predict_model", Category.MLD, [f1], "vector",
                   model=pipe.model, model_name="m", task="regression",
                   proba=False)
    f2 = plan.emit("featurize", Category.MLD, [scan], "matrix",
                   featurizers=clone.featurizers, pipeline_name="m",
                   input_columns=tuple(FEATS))
    p2 = plan.emit("predict_model", Category.MLD, [f2], "vector",
                   model=clone.model, model_name="m", task="regression",
                   proba=False)
    a1 = plan.emit("attach_column", Category.RA, [scan, p1], "table",
                   name="s1")
    a2 = plan.emit("attach_column", Category.RA, [a1, p2], "table",
                   name="s2")
    plan.output = a2

    from repro.core.optimizer import OptimizationReport
    from repro.core.rules import subplan_dedup
    report = OptimizationReport()
    changed = subplan_dedup.apply(plan, store, None, report)
    assert changed
    preds = [n for n in plan.nodes.values() if n.op == "predict_model"]
    feats = [n for n in plan.nodes.values() if n.op == "featurize"]
    assert len(preds) == 1 and len(feats) == 1, plan.pretty()


def test_udf_subtrees_are_never_merged_or_cached(store):
    plan = Plan()
    scan = plan.emit("scan", Category.RA, [], "table", table="patient_info")
    u1 = plan.emit("udf", Category.UDF, [scan], "vector",
                   fn=lambda cols: cols["age"] * 2)
    u2 = plan.emit("udf", Category.UDF, [scan], "vector",
                   fn=lambda cols: cols["age"] * 2)
    a1 = plan.emit("attach_column", Category.RA, [scan, u1], "table",
                   name="x")
    a2 = plan.emit("attach_column", Category.RA, [a1, u2], "table",
                   name="y")
    plan.output = a2
    from repro.core.optimizer import OptimizationReport
    from repro.core.rules import subplan_dedup
    before = len(plan.nodes)
    subplan_dedup.apply(plan, store, None, OptimizationReport())
    udfs = [n for n in plan.nodes.values() if n.op == "udf"]
    assert len(udfs) == 2, "UDF subtrees must never merge"
    assert len(plan.nodes) == before
