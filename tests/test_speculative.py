"""Speculative decoding: exactness + acceptance-rate properties."""

import numpy as np
import jax
import pytest

from repro.configs import get_config, reduced_config
from repro.models import build_model
from repro.serve.speculative import (SpecStats, greedy_decode,
                                     speculative_decode)


@pytest.fixture(scope="module")
def models():
    cfg = reduced_config(get_config("qwen2.5-14b"))
    target = build_model(cfg, remat=False)
    t_params = target.init_params(jax.random.PRNGKey(0))
    # draft: different (worse) weights, same family
    d_params = target.init_params(jax.random.PRNGKey(99))
    return cfg, target, t_params, d_params


def test_speculative_equals_greedy(models):
    cfg, model, t_params, d_params = models
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    ref = greedy_decode(model, t_params, prompt, 10)
    out, stats = speculative_decode(model, t_params, model, d_params,
                                    prompt, 10, k=3)
    assert out == ref          # bit-identical to target greedy
    assert stats.proposed > 0


def test_self_draft_accepts_most(models):
    """Draft == target: acceptance near 1 (the draft runs the incremental
    bf16-KV path, the verifier the full forward; ulp-level argmax ties can
    cost an occasional rejection — correctness is unaffected)."""
    cfg, model, t_params, _ = models
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    out, stats = speculative_decode(model, t_params, model, t_params,
                                    prompt, 8, k=4)
    assert stats.acceptance_rate >= 0.5
    assert out == greedy_decode(model, t_params, prompt, 8)


def test_fewer_target_calls_than_tokens(models):
    cfg, model, t_params, _ = models
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    n = 12
    out, stats = speculative_decode(model, t_params, model, t_params,
                                    prompt, n, k=4)
    # even with imperfect acceptance, verify calls < tokens generated
    assert stats.target_calls < n
