"""Serving engine tests: continuous batching, prefix cache, determinism."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced_config
from repro.models import build_model
from repro.serve import InferenceEngine, Request, ServeConfig


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = reduced_config(get_config("qwen2.5-14b"))
    model = build_model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(rng, cfg, n=8):
    return rng.integers(0, cfg.vocab_size, n).astype(np.int32)


def test_continuous_batching_completes_all(tiny_lm):
    cfg, model, params = tiny_lm
    eng = InferenceEngine(model, ServeConfig(n_slots=2, max_len=48,
                                             eos_token=-1))
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=_prompt(rng, cfg),
                           max_new_tokens=4))
    eng.run_until_drained(params)
    assert len(eng.completed) == 5
    assert all(len(r.output) == 4 for r in eng.completed)
    assert all(r.first_token_at is not None for r in eng.completed)


def test_greedy_decode_independent_of_batching(tiny_lm):
    """A request's greedy output must not depend on which other requests
    share the batch (slot isolation)."""
    cfg, model, params = tiny_lm
    rng = np.random.default_rng(1)
    p = _prompt(rng, cfg)

    def run(extra):
        eng = InferenceEngine(model, ServeConfig(n_slots=3, max_len=48,
                                                 eos_token=-1,
                                                 prefix_cache=False))
        eng.submit(Request(rid=0, prompt=p.copy(), max_new_tokens=5))
        for i, q in enumerate(extra):
            eng.submit(Request(rid=10 + i, prompt=q, max_new_tokens=5))
        eng.run_until_drained(params)
        return next(r.output for r in eng.completed if r.rid == 0)

    alone = run([])
    crowded = run([_prompt(rng, cfg), _prompt(rng, cfg)])
    assert alone == crowded


def test_prefix_cache_hit(tiny_lm):
    cfg, model, params = tiny_lm
    eng = InferenceEngine(model, ServeConfig(n_slots=2, max_len=48,
                                             eos_token=-1))
    rng = np.random.default_rng(2)
    p = _prompt(rng, cfg)
    eng.submit(Request(rid=0, prompt=p, max_new_tokens=3))
    eng.run_until_drained(params)
    assert len(eng._prefix_cache) == 1
    eng.submit(Request(rid=1, prompt=p.copy(), max_new_tokens=3))
    eng.run_until_drained(params)
    assert len(eng._prefix_cache) == 1      # reused, not re-added
    outs = {r.rid: r.output for r in eng.completed}
    assert outs[0] == outs[1]


def test_eos_stops_early(tiny_lm):
    cfg, model, params = tiny_lm
    # force eos: whatever greedy emits first becomes the eos token
    rng = np.random.default_rng(3)
    p = _prompt(rng, cfg)
    probe = InferenceEngine(model, ServeConfig(n_slots=1, max_len=48,
                                               eos_token=-1))
    probe.submit(Request(rid=0, prompt=p, max_new_tokens=1))
    probe.run_until_drained(params)
    first = probe.completed[0].output[0]
    eng = InferenceEngine(model, ServeConfig(n_slots=1, max_len=48,
                                             eos_token=first))
    eng.submit(Request(rid=1, prompt=p.copy(), max_new_tokens=8))
    eng.run_until_drained(params)
    assert len(eng.completed[0].output) == 1
