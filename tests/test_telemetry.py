"""End-to-end request tracing + unified metrics registry (ISSUE 9).

Four layers of guarantees:

1. **Span mechanics are exact** (ManualClock, no threads): durations,
   nesting, worker ``add_span`` tracks, events, and the Chrome-trace
   export shape are pinned to deterministic clock readings.
2. **MetricsRegistry semantics**: counter/gauge/histogram keying by
   ``(name, labels)``, pull-time collectors sampled at read time, and
   Prometheus text rendering (TYPE lines, cumulative ``le`` buckets).
3. **Trace completeness per serving path**: cold compile, warm hit,
   coalesced groups, result-cache splice, sharded morsels, and the
   shuffle exchange each leave their signature spans in the request's
   trace — the observability contract the EXPLAIN/trace tooling reads.
4. **Off is free**: ``telemetry=False`` yields the shared NULL_TRACE
   (zero spans retained, ``ticket.trace()`` is None) and zero hot-path
   registry writes, while pull-time collectors keep working.

Plus the operator-level EXPLAIN ANALYZE contract: on an external-model
shuffle-join query (known per-operator latency floor) the per-operator
measured times must sum to within 20% of the measured end-to-end wall
time.
"""

import json

import numpy as np
import pytest

from repro.core import ExecutionConfig, ModelStore, OptimizerConfig
from repro.core.ir import Plan
from repro.data import hospital_tables
from repro.ml import (DecisionTree, LogisticRegression, Pipeline,
                      PipelineMetadata, StandardScaler)
from repro.relational.table import Table
from repro.serve import (NULL_TRACE, AdmissionConfig, ManualClock,
                         MetricsRegistry, PredictionService, Trace,
                         chrome_trace)

pytestmark = pytest.mark.tier1

FEATS = ["age", "gender", "pregnant", "rcount"]
SQL = "SELECT pid, age FROM patient_info WHERE age > 30"
SQL_A = "SELECT pid, PREDICT(MODEL='m') AS score FROM patient_info"
SQL_B = "SELECT pid, age, PREDICT(MODEL='m') AS score FROM patient_info"


def _make_store(n_rows=300, seed=7):
    store = ModelStore()
    for n, t in hospital_tables(n_rows, seed=seed).items():
        store.register_table(n, t)
    pi = store.get_table("patient_info")
    data = {c: np.asarray(pi.column(c)) for c in pi.names}
    sc = StandardScaler(FEATS).fit(data)
    # depth 6: > inline_max_nodes, so the predict subtree stays cacheable
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=6),
                    PipelineMetadata(name="m", task="regression"))
    pipe.fit({k: data[k] for k in FEATS}, data["length_of_stay"])
    store.register_model("m", pipe)
    return store


@pytest.fixture(scope="module")
def store():
    return _make_store()


def _sub(full: Table, lo: int, n: int) -> Table:
    return Table({k: v[lo:lo + n] for k, v in full.columns.items()},
                 full.valid[lo:lo + n], full.schema)


# ---------------------------------------------------------------------------
# 1. Span mechanics (ManualClock — exact durations)
# ---------------------------------------------------------------------------

def test_span_durations_exact_on_manual_clock():
    clock = ManualClock()
    tr = Trace(clock, trace_id=7, name="q")
    with tr.span("parse"):
        clock.advance(0.25)
    with tr.span("execute", rows=10) as ex:
        clock.advance(1.5)
        with tr.span("inner"):
            clock.advance(0.5)
    clock.advance(0.125)
    tr.finish()
    tr.finish()                             # idempotent: first stamp wins

    parse, execute = tr.roots
    assert parse.duration == 0.25
    assert execute is ex and execute.duration == 2.0
    assert execute.attrs == {"rows": 10}
    (inner,) = execute.children
    assert inner.duration == 0.5
    assert tr.total_s == 2.375
    assert tr.span_names() == ["parse", "execute", "inner"]
    assert tr.find("inner").duration == 0.5
    assert "execute 2000.000ms" in tr.pretty()


def test_worker_add_span_and_events():
    clock = ManualClock()
    tr = Trace(clock)
    tr.event("cache", result="hit")
    with tr.span("execute"):
        # overlapping worker spans, recorded out-of-band with device tids
        tr.add_span("shard_wave", 0.0, 0.5, tid=1, device=0)
        tr.add_span("shard_wave", 0.0, 0.75, tid=2, device=1)
        clock.advance(0.75)
    ev = tr.find("cache")
    assert ev.duration == 0.0 and ev.attrs == {"result": "hit"}
    waves = [s for s in tr.spans() if s.name == "shard_wave"]
    assert [w.tid for w in waves] == [1, 2]
    # workers parent under the phase span that was open when they recorded
    assert all(w in tr.find("execute").children for w in waves)


def test_chrome_trace_export_shape(tmp_path):
    clock = ManualClock()
    tr = Trace(clock, trace_id=3, name="q1")
    with tr.span("execute", rows=4):
        clock.advance(0.5)
    tr.finish()
    path = tmp_path / "trace.json"
    doc = chrome_trace([tr], path=str(path))
    assert doc == json.loads(path.read_text())
    meta, span = doc["traceEvents"]
    assert meta["ph"] == "M" and meta["args"]["name"] == "q1 #3"
    assert span["ph"] == "X" and span["name"] == "execute"
    assert span["dur"] == 0.5e6 and span["args"] == {"rows": 4}


def test_null_trace_is_inert():
    with NULL_TRACE.span("anything", x=1) as s:
        assert s is None
    assert NULL_TRACE.event("e") is None
    assert NULL_TRACE.add_span("w", 0.0, 1.0) is None
    assert not NULL_TRACE.enabled
    assert NULL_TRACE.span_names() == []
    assert NULL_TRACE.to_chrome_events() == []


# ---------------------------------------------------------------------------
# 2. MetricsRegistry semantics
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_labels():
    reg = MetricsRegistry()
    reg.inc("req_total")
    reg.inc("req_total", 2.0)
    reg.inc("req_total", labels={"tenant": "a"})
    reg.set_gauge("depth", 4)
    snap = reg.snapshot()
    assert snap["counters"]["req_total"] == 3.0
    assert snap["counters"]["req_total{tenant=a}"] == 1.0
    assert snap["gauges"]["depth"] == 4.0
    assert reg.writes == 4


def test_registry_histogram_render_cumulative():
    reg = MetricsRegistry()
    for v in (0.3, 0.4, 99.0):
        reg.observe("lat_seconds", v, buckets=(0.5, 1.0))
    text = reg.render()
    assert "# TYPE lat_seconds histogram" in text
    assert 'lat_seconds_bucket{le="0.5"} 2' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text
    assert "lat_seconds_sum 99.7" in text


def test_registry_collectors_sampled_at_read_time():
    reg = MetricsRegistry()
    state = {"n": 1}
    unsub = reg.add_collector(
        lambda: [("live_total", "counter", state["n"], None),
                 ("live_depth", "gauge", 2.0, {"q": "x"})])
    assert reg.snapshot()["counters"]["live_total"] == 1.0
    state["n"] = 5
    snap = reg.snapshot()
    assert snap["counters"]["live_total"] == 5.0     # re-sampled, not cached
    assert snap["gauges"]["live_depth{q=x}"] == 2.0
    assert reg.writes == 0                           # collection is a read
    unsub()
    assert "live_total" not in reg.snapshot()["counters"]


# ---------------------------------------------------------------------------
# 3. Trace completeness per serving path
# ---------------------------------------------------------------------------

def test_queue_wait_span_is_exact_on_manual_clock(store):
    clock = ManualClock()
    svc = PredictionService(store, clock=clock, admission=AdmissionConfig(
        latency_budget_s=1.0, background=False))
    ticket = svc.submit(SQL)
    clock.advance(1.1)
    assert svc.admission_tick() == 1
    ticket.result(timeout=0)
    tr = ticket.trace()
    assert tr is not None and tr.finished is not None
    qw = tr.find("queue_wait")
    assert qw.duration == pytest.approx(1.1)
    assert qw.attrs["reason"] == "deadline"
    assert svc.traces()[-1] is tr
    svc.close()


def test_cold_then_warm_trace_spans(store):
    svc = PredictionService(store)
    svc.run(SQL)
    svc.run(SQL)
    cold, warm = svc.traces()
    assert cold.name == SQL
    for name in ("parse", "queue_wait", "optimize", "codegen", "execute"):
        assert cold.find(name) is not None, name
    assert cold.find("executable_cache").attrs["result"] == "miss"
    warm_names = warm.span_names()
    assert warm.find("executable_cache").attrs["result"] == "hit"
    assert "optimize" not in warm_names and "codegen" not in warm_names
    assert warm.find("execute") is not None
    svc.close()


def test_coalesced_member_gets_event_head_gets_execute(store):
    clock = ManualClock()
    svc = PredictionService(store, clock=clock, admission=AdmissionConfig(
        latency_budget_s=1.0, background=False))
    t1 = svc.submit(SQL)
    t2 = svc.submit(SQL)
    clock.advance(1.5)
    assert svc.admission_tick() == 2
    head, rider = t1.trace(), t2.trace()
    assert head.find("execute").attrs["coalesced"] == 1
    assert rider.find("coalesced").attrs["group"] == 2
    assert rider.find("execute") is None
    assert len(svc.traces()) == 2
    svc.close()


def test_splice_trace_visible_in_second_query(store):
    svc = PredictionService(store)
    svc.run(SQL_A)
    svc.run(SQL_B)
    assert svc.stats.spliced_executions == 1
    first, second = svc.traces()
    assert first.find("result_cache_splice") is None
    splice = second.find("result_cache_splice")
    assert splice is not None and splice.attrs["hit"] is True
    assert "patient_info" in splice.attrs["subtree"]
    svc.close()


def test_sharded_trace_carries_shard_waves():
    rng = np.random.RandomState(0)
    n = 1200
    t = Table.from_pydict({
        "pid": np.arange(n),
        "age": np.sort(rng.randint(0, 100, n)).astype(np.int32)})
    store = ModelStore()
    store.register_table("people", t, partition_rows=200)
    svc = PredictionService(store, execution_config=ExecutionConfig(
        sharded=True, shard_min_bucket_rows=32))
    svc.run("SELECT pid FROM people WHERE age < 30")
    assert svc.stats.sharded_executions == 1
    (tr,) = svc.traces()
    waves = [s for s in tr.spans() if s.name == "shard_wave"]
    assert waves and all(w.tid >= 1 for w in waves)
    assert sum(w.attrs["partitions"] for w in waves) \
        == svc.stats.partitions_scanned
    svc.close()


def _exchange_store(n_pids=48, per_pid=4, seed=3):
    """Fact/dim pair partitioned on *different* keys, so the join can only
    shard through the hash-repartition exchange (test_exchange idiom)."""
    rng = np.random.RandomState(seed)
    n_rows = n_pids * per_pid
    visits = Table.from_pydict({
        "oid": np.arange(n_rows, dtype=np.int64),
        "pid": rng.permutation(np.repeat(
            np.arange(n_pids, dtype=np.int32), per_pid)),
        "amount": rng.uniform(0.0, 9.0, n_rows).astype(np.float32)})
    patients = Table.from_pydict({
        "pid": np.arange(n_pids, dtype=np.int32),
        "age": rng.uniform(0.0, 99.0, n_pids).astype(np.float32)})
    store = ModelStore()
    store.register_table("visits", visits, partition_by="oid",
                         partition_bounds=[n_rows // 2])
    store.register_table("patients", patients, partition_by="pid",
                         partition_bounds=[n_pids // 2])
    return store


def _join_plan():
    plan = Plan()
    v = plan.emit("scan", "RA", [], "table", table="visits")
    p = plan.emit("scan", "RA", [], "table", table="patients")
    plan.output = plan.emit("join", "RA", [v, p], "table", on="pid",
                            how="inner")
    return plan


def test_exchange_trace_spans_and_placement_attrs():
    svc = PredictionService(_exchange_store(), execution_config=
        ExecutionConfig(
            sharded=True, shard_min_bucket_rows=4, shard_morsel_rows=16,
            shard_exchange_cost_gate=False))
    svc.run(_join_plan())
    assert svc.stats.exchange_executions == 1
    (tr,) = svc.traces()
    build = tr.find("exchange_build")
    assert build.attrs["on"] == "pid"
    assert build.attrs["n_buckets"] >= 1          # ExchangePlacement.describe
    assert build.attrs["anchor_rows_total"] == 192
    buckets = [s for s in tr.spans() if s.name == "exchange_bucket"]
    assert buckets and all(b.tid >= 1 for b in buckets)
    scatter = tr.find("exchange_scatter")
    assert scatter is not None and scatter.attrs["rows"] == 192
    svc.close()


def test_export_traces_writes_chrome_json(store, tmp_path):
    svc = PredictionService(store)
    svc.run(SQL)
    path = tmp_path / "traces.json"
    doc = svc.export_traces(str(path))
    assert path.exists()
    names = {e["name"] for e in doc["traceEvents"]}
    assert "execute" in names and "process_name" in names
    svc.close()


def test_trace_ring_capacity_bounds_retention(store):
    svc = PredictionService(store, trace_capacity=2)
    for _ in range(5):
        svc.run(SQL)
    assert len(svc.traces()) == 2
    assert len(svc.traces(1)) == 1
    svc.close()


# ---------------------------------------------------------------------------
# 4. telemetry=False is free
# ---------------------------------------------------------------------------

def test_telemetry_off_zero_spans_zero_writes(store):
    svc = PredictionService(store, telemetry=False)
    ticket = svc.submit(SQL)
    svc.flush()
    ticket.result(timeout=5)
    svc.run(SQL)
    assert svc.traces() == []
    assert ticket.trace() is None
    assert svc.metrics.writes == 0                # no hot-path mutations
    # pull-time collectors still work: stats stay the source of truth
    snap = svc.metrics_snapshot()
    assert snap["counters"]["repro_submitted_total"] == 2.0
    assert snap["counters"]["repro_cache_hits_total"] == 1.0
    svc.close()


def test_telemetry_on_writes_and_prometheus_text(store):
    svc = PredictionService(store)
    svc.run(SQL)
    assert svc.metrics.writes >= 3      # queue wait + exec + compile observes
    text = svc.metrics_text()
    assert "# TYPE repro_queue_wait_seconds histogram" in text
    assert "repro_exec_seconds_count 1" in text
    assert "repro_compile_seconds_count 1" in text
    assert "repro_plans_compiled_total 1" in text
    assert "repro_batch_executions_total 1" in text
    assert "repro_admission_queue_depth_high_water 1" in text
    svc.close()


# ---------------------------------------------------------------------------
# 5. EXPLAIN ANALYZE
# ---------------------------------------------------------------------------

EXTERNAL_LATENCY_S = 20e-3


def _explain_store(n_pids=64, per_pid=4, seed=11):
    """Shuffle-join-shaped store with an *external*-flavor model: every
    operator above the scans costs real wall time (the external hop has a
    simulated 20ms floor), so per-operator times must account for the
    end-to-end measurement."""
    rng = np.random.RandomState(seed)
    store = _exchange_store(n_pids=n_pids, per_pid=per_pid, seed=seed)
    visits = store.get_table("visits")
    patients = store.get_table("patients")
    age = np.asarray(patients.column("age"))
    feats = ["age", "amount"]
    data = {"age": age[np.asarray(visits.column("pid"))],
            "amount": np.asarray(visits.column("amount"))}
    y = (data["age"] * 0.02 + data["amount"] * 0.1
         + rng.randn(len(data["age"])) > 1.0).astype(np.int32)
    sc = StandardScaler(feats).fit(data)
    pipe = Pipeline([sc], LogisticRegression(steps=25),
                    PipelineMetadata(name="risk", task="classification",
                                     flavor="external"))
    pipe.fit(data, y)
    store.register_model("risk", pipe)
    return store, pipe


def _predict_join_plan(pipe):
    plan = _join_plan()
    j = plan.output
    f = plan.emit("featurize", "MLD", [j], "matrix", pipeline_name="risk",
                  featurizers=pipe.featurizers,
                  input_columns=pipe.input_columns())
    m = plan.emit("predict_model", "MLD", [f], "matrix", model=pipe.model,
                  model_name="risk", proba=True, task="classification",
                  flavor="external")
    plan.output = plan.emit("attach_column", "RA", [j, m], "table", name="p")
    return plan


def test_explain_analyze_operator_times_account_for_e2e():
    store, pipe = _explain_store()
    svc = PredictionService(
        store,
        optimizer_config=OptimizerConfig(enable_model_inlining=False,
                                         enable_nn_translation=False),
        execution_config=ExecutionConfig(
            external_latency_s=EXTERNAL_LATENCY_S))
    ex = svc.explain(_predict_join_plan(pipe), analyze=True)
    assert ex.analyze and ex.total_s > 0
    op_names = [n.op for _, n in ex.operators()]
    assert "join" in op_names and "predict_model" in op_names
    measured = ex.measured_s
    # the acceptance bound: per-operator sum within 20% of end-to-end
    assert measured == pytest.approx(ex.total_s, rel=0.2)
    # the external hop's 20ms floor is visible on its operator
    pm = [nid for nid, n in ex.plan.nodes.items()
          if n.op == "predict_model"]
    assert pm and ex.samples[pm[0]][0] >= EXTERNAL_LATENCY_S * 0.5
    text = ex.pretty()
    assert "predict_model" in text and "actual time=" in text
    assert "end-to-end" in text
    svc.close()


def test_explain_without_analyze_renders_plan_only(store):
    svc = PredictionService(store)
    ex = svc.explain(SQL)
    assert not ex.analyze and ex.samples == {}
    text = ex.pretty()
    assert "scan [patient_info]" in text
    assert "actual time=" not in text
    svc.close()
