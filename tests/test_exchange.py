"""Hash-repartition shuffle exchange: *any* equi-join shards.

Four layers:

1. **Shuffle-planner units** — ``hash_buckets`` determinism (float ``-0.0``
   folding, bools, full-range coverage), ``choose_bucket_count`` doubling,
   ``plan_exchange`` row conservation / skew handling / pow-2 capacities,
   ``take_pad`` zero-padding.
2. **Service integration** — a non-co-partitioned equi-join routes through
   the exchange (``exchange_executions``), matches whole-table execution on
   the validity mask and valid rows (join) or bitwise (join + two-phase
   aggregation), repeats warm with zero compiles, and is independent of
   bucket-count knobs (placement independence).  Multi-aggregation plans
   split every aggregation, including one fed by an exchange join.
3. **Cost gate** — with the gate on (default), tiny tables fall back to
   whole-table execution (``exchange_fallbacks``) and still agree;
   ``shard_exchange=False`` disables the path outright.
4. **Bit-exactness property** (hypothesis + seeded twin): random partition
   layouts (misaligned bounds, empty partitions), row counts, validity
   (NULL join keys), and key skew (all rows one bucket) — exchange ==
   whole-table bitwise.  Change the seeded sweep and the property together.
"""

import numpy as np
import pytest

from repro.core import ExecutionConfig, ModelStore
from repro.core.ir import Plan
from repro.serve import PredictionService
from repro.serve.exchange import (choose_bucket_count, hash_buckets,
                                  plan_exchange, take_pad)

pytestmark = pytest.mark.tier1

AGG_FNS = ["sum", "count", "avg", "min", "max"]


def _table(**cols):
    from repro.relational.table import Table
    valid = cols.pop("valid", None)
    t = Table.from_pydict({k: np.asarray(v) for k, v in cols.items()})
    if valid is not None:
        t = t.with_valid(np.asarray(valid, bool))
    return t


def _xc_store(n_pids=12, n_rows=60, fact_bounds=(4, 8), seed=0,
              fact_valid=None, dim_valid=None, fact_pids=None):
    """Fact ``visits`` + dim ``patients``, both range-partitioned on
    ``pid`` but with *misaligned* bounds (dim gets one extra partition),
    so ``compatible_partitioning`` is False and the only way to shard the
    join is the hash-repartition exchange."""
    rng = np.random.RandomState(seed)
    if fact_pids is None:
        fact_pids = rng.randint(0, n_pids, n_rows)
    fact_pids = np.sort(np.asarray(fact_pids, np.int32))
    visits = _table(pid=fact_pids,
                    amount=rng.randint(-4, 5, len(fact_pids))
                    .astype(np.float32),
                    valid=fact_valid)
    patients = _table(pid=np.arange(n_pids, dtype=np.int32),
                      region=(np.arange(n_pids) % 3).astype(np.int32),
                      weight=rng.randint(0, 4, n_pids).astype(np.float32),
                      valid=dim_valid)
    dim_bounds = [b + 1 for b in fact_bounds] + [max(fact_bounds) + 2]
    store = ModelStore()
    store.register_table("visits", visits, partition_by="pid",
                         partition_bounds=list(fact_bounds))
    store.register_table("patients", patients, partition_by="pid",
                         partition_bounds=dim_bounds)
    return store, visits, patients


def _join_plan(filter_pred=None):
    plan = Plan()
    v = plan.emit("scan", "RA", [], "table", table="visits")
    if filter_pred is not None:
        v = plan.emit("filter", "RA", [v], "table", predicate=filter_pred)
    p = plan.emit("scan", "RA", [], "table", table="patients")
    plan.output = plan.emit("join", "RA", [v, p], "table", on="pid",
                            how="inner")
    return plan


def _join_agg_plan(aggs=None, key="region", num_groups=3,
                   filter_pred=None):
    plan = _join_plan(filter_pred)
    aggs = aggs if aggs is not None else {
        "total": ("sum", "amount"), "n": ("count", None),
        "avg_a": ("avg", "amount"), "lo": ("min", "amount"),
        "hi": ("max", "amount")}
    plan.output = plan.emit("group_agg", "RA", [plan.output], "table",
                            key=key, aggs=aggs, num_groups=num_groups)
    return plan


def _sharded(store, **knobs):
    knobs.setdefault("shard_min_bucket_rows", 4)
    knobs.setdefault("shard_morsel_rows", 16)
    knobs.setdefault("shard_exchange_cost_gate", False)
    return PredictionService(store, execution_config=ExecutionConfig(
        sharded=True, **knobs))


def _assert_tables_equal(got, want):
    assert got.capacity == want.capacity
    assert (np.asarray(got.valid) == np.asarray(want.valid)).all()
    assert set(got.columns) == set(want.columns)
    for k in want.columns:
        g, w = np.asarray(got.columns[k]), np.asarray(want.columns[k])
        assert (g == w).all(), k


def _assert_same_valid_rows(got, want):
    vg, vw = np.asarray(got.valid), np.asarray(want.valid)
    assert set(got.columns) == set(want.columns)
    for k in want.columns:
        g = np.asarray(got.columns[k])[vg]
        w = np.asarray(want.columns[k])[vw]
        assert g.shape == w.shape and (g == w).all(), k


# ---------------------------------------------------------------------------
# 1. Shuffle-planner units
# ---------------------------------------------------------------------------

def test_hash_buckets_deterministic_and_covering():
    keys = np.arange(100, dtype=np.int64)
    b = hash_buckets(keys, 8)
    assert b.dtype == np.int64
    assert b.min() >= 0 and b.max() < 8
    assert set(b.tolist()) == set(range(8))      # splitmix64 spreads
    assert (hash_buckets(keys, 8) == b).all()    # pure value hashing


def test_hash_buckets_key_dtypes_agree():
    # equal-comparing keys must share a bucket whatever their container:
    # -0.0 == +0.0, f32 widens exactly to f64, ints hash their value
    assert (hash_buckets(np.asarray([-0.0]), 4)
            == hash_buckets(np.asarray([0.0]), 4)).all()
    f32 = hash_buckets(np.arange(32, dtype=np.float32), 16)
    f64 = hash_buckets(np.arange(32, dtype=np.float64), 16)
    assert (f32 == f64).all()
    b = hash_buckets(np.asarray([True, False, True]), 4)
    assert (b[0] == b[2]) and b.min() >= 0 and b.max() < 4


def test_choose_bucket_count_doubles_past_morsel_cap():
    assert choose_bucket_count(100, 4, morsel_rows=64) == 4
    assert choose_bucket_count(1000, 4, morsel_rows=64) == 16
    assert choose_bucket_count(0, 0, morsel_rows=64) == 1
    assert choose_bucket_count(10, 8, morsel_rows=64) == 8


def test_plan_exchange_conserves_rows_and_aligns_sides():
    rng = np.random.RandomState(3)
    a_keys = rng.randint(0, 20, 100).astype(np.int64)
    s_keys = np.arange(20, dtype=np.int64)
    pl = plan_exchange(a_keys, s_keys, 8, min_bucket_rows=4)
    # every row lands in exactly one bucket, ascending within each
    cat = np.concatenate([i for i in pl.anchor_index])
    assert sorted(cat.tolist()) == list(range(100))
    for idx in pl.anchor_index:
        assert (np.diff(idx) > 0).all() if len(idx) > 1 else True
    # same key value -> same bucket on both sides
    ab = hash_buckets(a_keys, 8)
    sb = hash_buckets(s_keys, 8)
    assert (sb[a_keys] == ab).all()
    # pow-2 capacities cover the largest bucket
    assert pl.anchor_rows >= max(len(i) for i in pl.anchor_index)
    assert pl.anchor_rows & (pl.anchor_rows - 1) == 0
    assert pl.total_rows == 100


def test_plan_exchange_skew_all_keys_one_bucket():
    keys = np.full(40, 7, dtype=np.int64)
    pl = plan_exchange(keys, keys[:10], 8, min_bucket_rows=4)
    assert len(pl.active_buckets) == 1
    (b,) = pl.active_buckets
    assert len(pl.anchor_index[b]) == 40 and len(pl.side_index[b]) == 10
    assert pl.anchor_rows >= 40
    assert pl.n_waves(8) == 1                    # one device does it all
    assert pl.bytes_moved(8, 8) == 50 * 8


def test_take_pad_zero_pads_to_capacity():
    arr = np.arange(10, dtype=np.float32)
    out = take_pad(arr, np.asarray([3, 5, 7]), 8)
    assert out.shape == (8,)
    assert (out[:3] == [3, 5, 7]).all() and (out[3:] == 0).all()
    empty = take_pad(arr, np.asarray([], np.int64), 4)
    assert empty.shape == (4,) and (empty == 0).all()


# ---------------------------------------------------------------------------
# 2. Service integration
# ---------------------------------------------------------------------------

def test_exchange_join_valid_rows_exact():
    store, *_ = _xc_store(n_pids=12, n_rows=60)
    base = PredictionService(store)
    svc = _sharded(store)
    plan = _join_plan()
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    # inner join: unmatched left rows carry garbage-but-masked right
    # columns, so equality is on the mask and the valid rows
    assert (np.asarray(got.valid) == np.asarray(want.valid)).all()
    _assert_same_valid_rows(got, want)
    info = svc.shard_info()
    assert info["exchange_executions"] == 1
    assert info["exchange_fallbacks"] == 0
    assert info["exchange_bytes_moved"] > 0
    assert svc.stats.sharded_executions == 1
    base.close(); svc.close()


def test_exchange_join_agg_bit_exact():
    store, *_ = _xc_store(n_pids=12, n_rows=80, fact_bounds=(3, 6, 9))
    base = PredictionService(store)
    svc = _sharded(store)
    plan = _join_agg_plan()
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    _assert_tables_equal(got, want)
    info = svc.shard_info()
    assert info["exchange_executions"] == 1
    assert info["agg_combines"] == 1
    base.close(); svc.close()


def test_exchange_warm_repeats_compile_nothing():
    store, *_ = _xc_store()
    svc = _sharded(store)
    plan = _join_agg_plan()
    svc.run(plan.copy())
    before = (svc.stats.cache_misses, svc.stats.shard_compiles,
              svc.stats.jit_traces)
    for _ in range(3):
        svc.run(plan.copy())
    after = (svc.stats.cache_misses, svc.stats.shard_compiles,
             svc.stats.jit_traces)
    assert before == after          # bucket capacities are data-determined
    assert svc.shard_info()["exchange_executions"] == 4
    svc.close()


def test_exchange_placement_independent():
    """Different bucket-count knobs (morsel cap drives
    ``choose_bucket_count``) produce bitwise-identical results — the
    scatter-back contract makes placement unobservable."""
    store, *_ = _xc_store(n_pids=12, n_rows=80, fact_bounds=(3, 6, 9))
    plan = _join_agg_plan()
    svc_few = _sharded(store, shard_morsel_rows=1 << 16)
    svc_many = _sharded(store, shard_morsel_rows=8)
    got_few = svc_few.run(plan.copy())
    got_many = svc_many.run(plan.copy())
    _assert_tables_equal(got_many, got_few)
    assert svc_few.shard_info()["exchange_executions"] == 1
    assert svc_many.shard_info()["exchange_executions"] == 1
    svc_few.close(); svc_many.close()


def test_exchange_with_filter_and_null_keys():
    """Invalid (NULL-key) anchor rows ride the shuffle masked and scatter
    back to their original positions; a filter below the join narrows
    validity without breaking key intactness."""
    from repro.relational.expr import col
    store, *_ = _xc_store(
        n_rows=50, fact_valid=[i % 4 != 1 for i in range(50)],
        dim_valid=[i % 5 != 2 for i in range(12)])
    base = PredictionService(store)
    svc = _sharded(store)
    plan = _join_agg_plan(filter_pred=col("amount") > -2)
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    _assert_tables_equal(got, want)
    assert svc.shard_info()["exchange_executions"] == 1
    base.close(); svc.close()


def test_exchange_multi_agg_stages():
    """Two sibling aggregations — one over the exchange join, one over a
    plain partitioned scan — each split two-phase independently; the
    global stage joins the combined tables."""
    store, *_ = _xc_store(n_pids=10, n_rows=70)
    plan = Plan()
    v = plan.emit("scan", "RA", [], "table", table="visits")
    p = plan.emit("scan", "RA", [], "table", table="patients")
    j = plan.emit("join", "RA", [v, p], "table", on="pid", how="inner")
    a1 = plan.emit("group_agg", "RA", [j], "table", key="region",
                   aggs={"total": ("sum", "amount"), "n": ("count", None)},
                   num_groups=3)
    p2 = plan.emit("scan", "RA", [], "table", table="patients")
    a2 = plan.emit("group_agg", "RA", [p2], "table", key="region",
                   aggs={"w": ("sum", "weight")}, num_groups=3)
    plan.output = plan.emit("join", "RA", [a1, a2], "table", on="region",
                            how="inner")
    base = PredictionService(store)
    svc = _sharded(store)
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    assert (np.asarray(got.valid) == np.asarray(want.valid)).all()
    _assert_same_valid_rows(got, want)
    assert svc.stats.shard_agg_combines == 2     # one combine per stage
    assert svc.shard_info()["exchange_executions"] == 1
    assert svc.stats.sharded_executions == 1
    base.close(); svc.close()


def test_multi_agg_two_phase_without_exchange():
    """Join of two aggregation outputs: both aggs split two-phase even
    though the joining happens in the global stage."""
    store, *_ = _xc_store(n_pids=10, n_rows=70)
    plan = Plan()
    v = plan.emit("scan", "RA", [], "table", table="visits")
    a1 = plan.emit("group_agg", "RA", [v], "table", key="pid",
                   aggs={"total": ("sum", "amount")}, num_groups=10)
    p = plan.emit("scan", "RA", [], "table", table="patients")
    a2 = plan.emit("group_agg", "RA", [p], "table", key="pid",
                   aggs={"w": ("sum", "weight")}, num_groups=10)
    plan.output = plan.emit("join", "RA", [a1, a2], "table", on="pid",
                            how="inner")
    base = PredictionService(store)
    svc = _sharded(store)
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    assert (np.asarray(got.valid) == np.asarray(want.valid)).all()
    _assert_same_valid_rows(got, want)
    assert svc.stats.shard_agg_combines == 2
    assert svc.stats.sharded_executions == 1
    base.close(); svc.close()


# ---------------------------------------------------------------------------
# 3. Cost gate and kill switch
# ---------------------------------------------------------------------------

def test_cost_gate_falls_back_on_tiny_tables():
    store, *_ = _xc_store(n_pids=12, n_rows=60)
    base = PredictionService(store)
    svc = _sharded(store, shard_exchange_cost_gate=True)
    plan = _join_agg_plan()
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    _assert_tables_equal(got, want)
    info = svc.shard_info()
    assert info["exchange_fallbacks"] >= 1       # gate said not worth it
    assert info["exchange_executions"] == 0
    assert svc.stats.sharded_executions == 0     # whole-table execution
    base.close(); svc.close()


def test_shard_exchange_off_is_whole_table():
    store, *_ = _xc_store()
    base = PredictionService(store)
    svc = _sharded(store, shard_exchange=False)
    plan = _join_agg_plan()
    want = base.run(plan.copy())
    got = svc.run(plan.copy())
    _assert_tables_equal(got, want)
    info = svc.shard_info()
    assert info["exchange_executions"] == 0
    assert svc.stats.sharded_executions == 0
    base.close(); svc.close()


# ---------------------------------------------------------------------------
# 4. Bit-exactness property: exchange == whole-table over random shapes
# ---------------------------------------------------------------------------

def _check_exchange_bit_exact(n_pids, fact_pids, fact_valid, dim_valid,
                              fact_bounds, agg_fns, seed=0):
    store, *_ = _xc_store(n_pids=n_pids, fact_bounds=fact_bounds,
                          seed=seed, fact_valid=fact_valid,
                          dim_valid=dim_valid, fact_pids=fact_pids)
    aggs = {f"{fn}_{i}": (fn, "amount") for i, fn in enumerate(agg_fns)}
    plan = _join_agg_plan(aggs=aggs, key="region", num_groups=3)
    base = PredictionService(store, jit=False)
    svc = _sharded(store, shard_morsel_rows=8)
    try:
        want = base.run(plan.copy())
        got = svc.run(plan.copy())
        _assert_tables_equal(got, want)
        assert svc.shard_info()["exchange_executions"] == 1
    finally:
        base.close(); svc.close()


def test_exchange_randomized_sweep():
    """Seeded twin of the hypothesis property below (runs everywhere,
    mirrors the repo convention — change both together)."""
    rng = np.random.RandomState(23)
    for i in range(20):
        n_pids = int(rng.randint(1, 13))
        n_rows = int(rng.randint(1, 40))
        n_bounds = int(rng.randint(1, 5))
        bounds = sorted(int(b) for b in rng.randint(0, n_pids + 1,
                                                    n_bounds))
        if i % 4 == 0:          # key skew: every row in one hash bucket
            fact_pids = np.full(n_rows, rng.randint(0, n_pids))
        else:
            fact_pids = rng.randint(0, n_pids, n_rows)
        _check_exchange_bit_exact(
            n_pids=n_pids,
            fact_pids=fact_pids,
            fact_valid=rng.rand(n_rows) < rng.choice([0.0, 0.6, 1.0]),
            dim_valid=rng.rand(n_pids) < 0.9,
            fact_bounds=bounds,
            agg_fns=[AGG_FNS[rng.randint(len(AGG_FNS))]
                     for _ in range(rng.randint(1, 4))],
            seed=i)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @given(
        n_pids=st.integers(min_value=1, max_value=12),
        fact=st.lists(st.tuples(st.integers(0, 11),     # pid (clamped)
                                st.booleans()),         # valid
                      min_size=1, max_size=32),
        dim_valid_bits=st.lists(st.booleans(), min_size=12, max_size=12),
        bounds=st.lists(st.integers(0, 12), min_size=1, max_size=4),
        skew=st.booleans(),
        agg_fns=st.lists(st.sampled_from(AGG_FNS), min_size=1,
                         max_size=3),
    )
    @settings(max_examples=30, deadline=None)
    def test_exchange_bit_exact_property(n_pids, fact, dim_valid_bits,
                                         bounds, skew, agg_fns):
        """Hash-repartition exchange == whole-table execution, bitwise,
        across random misaligned partition layouts (empty partitions
        included), row counts, NULL join keys (invalid rows), and key
        skew (every row hashing to one bucket)."""
        pids = [min(p, n_pids - 1) for p, _m in fact]
        if skew:
            pids = [pids[0]] * len(pids)
        _check_exchange_bit_exact(
            n_pids=n_pids,
            fact_pids=pids,
            fact_valid=[m for _p, m in fact],
            dim_valid=dim_valid_bits[:n_pids],
            fact_bounds=sorted(bounds),
            agg_fns=agg_fns)
