"""Streaming ingest (ISSUE 10): ``ModelStore.append_rows`` as a first-class
ingest path — incremental zone maps, version lineage, append-surviving
caches, delta-only execution (row-local splice and IVM aggregate states),
whole-table fallbacks, and the per-request/per-tenant freshness SLA.
"""
import os

import numpy as np
import pytest

from repro.core import ModelStore
from repro.core.codegen import ExecutionConfig, add_compile_listener
from repro.core.partition import PartitionedTable
from repro.data import hospital_tables
from repro.ml import DecisionTree, Pipeline, PipelineMetadata, StandardScaler
from repro.relational.table import Table
from repro.serve import ManualClock, PredictionService, TenantPolicy

pytestmark = pytest.mark.tier1

FEATS = ["age", "gender", "pregnant", "rcount"]
SQL = ("SELECT pid, age, PREDICT(MODEL='los_pi') AS los "
       "FROM patient_info WHERE age > 30")


def _sub(table, lo, hi):
    return Table({k: v[lo:hi] for k, v in table.columns.items()},
                 table.valid[lo:hi], table.schema)


def _table(**cols):
    valid = cols.pop("valid", None)
    t = Table.from_pydict({k: np.asarray(v) for k, v in cols.items()})
    if valid is not None:
        t = t.with_valid(np.asarray(valid, bool))
    return t


@pytest.fixture(scope="module")
def ingest():
    """Small hospital slice + a fitted pipeline; ``full`` rows beyond
    ``base`` reuse base values, so appends drawn anywhere from ``full``
    keep merged column stats identical (the stats-stable append kind)."""
    # large enough that the optimizer decomposes PREDICT into the
    # featurize/predict_model pipeline (an _EXPENSIVE_OPS subtree): only
    # captured subtrees ride the result cache and hence the delta path
    full = hospital_tables(700, seed=11)["patient_info"]
    base = _sub(full, 0, 500)
    data = {c: np.asarray(base.column(c)) for c in base.names}
    sc = StandardScaler(FEATS).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=6),
                    PipelineMetadata(name="los_pi", task="regression"))
    pipe.fit({k: data[k] for k in FEATS}, data["length_of_stay"])
    return full, base, pipe


def _service(base, pipe, **kw):
    store = ModelStore()
    store.register_table("patient_info", base)
    store.register_model("los_pi", pipe)
    return store, PredictionService(store, **kw)


def _reference(cur, pipe):
    """Full recompute over exactly ``cur``'s rows on a cold service."""
    store = ModelStore()
    store.register_table("patient_info", cur)
    store.register_model("los_pi", pipe)
    svc = PredictionService(store)
    try:
        return svc.run(SQL)
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Incremental zone-map maintenance
# ---------------------------------------------------------------------------

def test_appended_zone_maps_match_rebuilt():
    rng = np.random.RandomState(3)
    full = _table(x=rng.randint(0, 12, 96).astype(np.int32),
                  v=rng.randn(96).astype(np.float32),
                  valid=rng.rand(96) > 0.2)
    base, batch = _sub(full, 0, 64), _sub(full, 64, 96)
    combined = base.concat_rows(batch)
    base_pt = PartitionedTable.build(base, 16)
    appended = base_pt.append(batch, combined)
    rebuilt = PartitionedTable.build(combined, 16)
    assert ([(p.start, p.stop) for p in appended.partitions]
            == [(p.start, p.stop) for p in rebuilt.partitions])
    for pa, pb in zip(appended.partitions, rebuilt.partitions):
        assert pa.zone == pb.zone, f"partition [{pa.start},{pa.stop})"
    # prefix Partition objects (and their zone maps) are reused, not rebuilt
    for old, new in zip(base_pt.partitions, appended.partitions):
        assert new is old


def test_append_opens_new_partition_at_old_boundary():
    # A ragged last partition is never extended: the batch starts its own
    # partition at the old capacity, so prefix pruning proofs stay valid.
    full = _table(x=np.arange(30, dtype=np.int32))
    base, batch = _sub(full, 0, 20), _sub(full, 20, 30)  # 16 + ragged 4
    appended = PartitionedTable.build(base, 16).append(
        batch, base.concat_rows(batch))
    starts = [(p.start, p.stop) for p in appended.partitions]
    assert starts[:2] == [(0, 16), (16, 20)]
    assert starts[2][0] == 20


def test_empty_append_is_identity():
    full = _table(x=np.arange(16, dtype=np.int32))
    base = _sub(full, 0, 16)
    pt = PartitionedTable.build(base, 8)
    out = pt.append(_sub(full, 16, 16), base)
    assert out.partitions == pt.partitions

    store = ModelStore()
    store.register_table("t", base, partition_rows=8)
    v0 = store.table_version("t")
    assert store.append_rows("t", _sub(full, 16, 16)) == v0


def test_keyed_append_rejects_straddling_keys():
    base = _table(k=np.asarray([0, 0, 1, 1, 2, 2], np.int32),
                  x=np.arange(6, dtype=np.float32))
    store = ModelStore()
    store.register_table("t", base, partition_rows=2, partition_by="k")
    bad = _table(k=np.asarray([2, 3], np.int32),
                 x=np.asarray([9.0, 9.0], np.float32))
    with pytest.raises(ValueError, match="strictly after"):
        store.append_rows("t", bad)
    good = _table(k=np.asarray([3, 3], np.int32),
                  x=np.asarray([9.0, 9.0], np.float32))
    store.append_rows("t", good)
    assert store.get_table("t").capacity == 8


# ---------------------------------------------------------------------------
# Version lineage + invalidation kinds
# ---------------------------------------------------------------------------

def test_append_lineage_and_invalidation_kind():
    rng = np.random.RandomState(0)
    full = _table(x=rng.randint(0, 8, 48).astype(np.int32))
    base = _sub(full, 0, 32)
    store = ModelStore()
    store.register_table("t", base)
    events = []
    unsub = store.add_invalidation_listener(
        lambda kind, name: events.append((kind, name)))
    v0 = store.table_version("t")

    # in-domain batch: stats provably unchanged -> kind='append'
    v1 = store.append_rows("t", _sub(full, 32, 40))
    assert v1 == v0 + 1
    assert events[-1] == ("append", "t")
    assert store.version_lineage("t") == ((v0, 32), (v1, 40))

    # out-of-domain batch: max extends -> full kind='table' invalidation
    store.append_rows("t", _table(x=np.asarray([99], np.int32)))
    assert events[-1] == ("table", "t")
    unsub()


# ---------------------------------------------------------------------------
# Delta serving: row-local splice
# ---------------------------------------------------------------------------

def test_row_local_delta_bitwise_and_zero_warm_compiles(
        ingest, assert_tables_equal):
    full, base, pipe = ingest
    store, svc = _service(base, pipe)
    compiles = []
    unsub = add_compile_listener(compiles.append)
    try:
        svc.run(SQL)
        cur = base
        for cycle in range(1, 4):
            batch = _sub(full, 10 * cycle, 10 * cycle + 30)
            store.append_rows("patient_info", batch)
            cur = cur.concat_rows(batch)
            n0, jt0 = len(compiles), svc.stats.jit_traces
            out = svc.run(SQL)
            if cycle >= 2:
                # append path is compile- and trace-free once the delta
                # twin exists (first cycle pays the residual + twin once)
                assert len(compiles) == n0
                assert svc.stats.jit_traces == jt0
            assert_tables_equal(out, _reference(cur, pipe))
        assert svc.stats.appends_observed == 3
        assert svc.stats.delta_serves >= 2
        assert svc.stats.delta_fallbacks == 0
        assert svc.stats.delta_rows_scanned <= 3 * 30 + 2
    finally:
        unsub()
        svc.close()


def test_delta_matches_full_recompute_random_appends(
        ingest, assert_tables_equal):
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import example, given, settings
    from hypothesis import strategies as st

    settings.register_profile("ingest", max_examples=8, deadline=None)
    settings.register_profile("ingest-nightly", max_examples=40,
                              deadline=None)
    settings.load_profile(
        "ingest-nightly"
        if os.environ.get("HYPOTHESIS_PROFILE") == "nightly" else "ingest")

    full, base, pipe = ingest

    @example(sizes=[0])            # empty batch: version must not move
    @example(sizes=[1])            # single-row batch
    @example(sizes=[0, 1, 48])
    @given(sizes=st.lists(st.integers(min_value=0, max_value=48),
                          min_size=1, max_size=3))
    @settings(deadline=None)
    def check(sizes):
        store, svc = _service(base, pipe)
        try:
            svc.run(SQL)
            cur = base
            for i, s in enumerate(sizes):
                lo = (17 * i) % 120
                batch = _sub(full, lo, lo + s)
                store.append_rows("patient_info", batch)
                cur = cur.concat_rows(batch)
                assert_tables_equal(svc.run(SQL), _reference(cur, pipe))
            assert svc.stats.delta_fallbacks == 0
        finally:
            svc.close()

    check()


# ---------------------------------------------------------------------------
# Delta serving: aggregate state reuse (incremental view maintenance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sql", [
    "SELECT SUM(x) AS s, COUNT(x) AS n, AVG(x) AS a, MIN(x) AS lo, "
    "MAX(x) AS hi FROM t",
    "SELECT k, SUM(x) AS s, COUNT(x) AS n, AVG(x) AS a FROM t GROUP BY k",
], ids=["global", "keyed"])
def test_agg_delta_bitwise_and_zero_compiles(sql, assert_tables_equal):
    rng = np.random.RandomState(5)
    full = _table(x=rng.randint(0, 9, 96).astype(np.float32),
                  k=rng.randint(0, 3, 96).astype(np.int32))
    base = _sub(full, 0, 64)
    store = ModelStore()
    store.register_table("t", base, partition_rows=8)
    svc = PredictionService(store, execution_config=ExecutionConfig(
        sharded=True, shard_min_bucket_rows=4, shard_morsel_rows=16))
    try:
        svc.run(sql)
        cur = base
        for cycle in range(1, 3):
            batch = _sub(full, 64 - 16 * cycle, 64 - 16 * (cycle - 1))
            store.append_rows("t", batch)
            cur = cur.concat_rows(batch)
            m0, jt0 = svc.stats.cache_misses, svc.stats.jit_traces
            sc0 = svc.stats.shard_compiles
            out = svc.run(sql)
            # delta partitions share the normal serve's shard signature, so
            # even the first delta cycle re-traces nothing
            assert svc.stats.cache_misses == m0
            assert svc.stats.jit_traces == jt0
            assert svc.stats.shard_compiles == sc0
            ref_store = ModelStore()
            ref_store.register_table("t", cur, partition_rows=8)
            ref_svc = PredictionService(ref_store)
            try:
                assert_tables_equal(out, ref_svc.run(sql))
            finally:
                ref_svc.close()
        assert svc.stats.delta_serves == 2
        assert svc.stats.delta_fallbacks == 0
        assert svc.stats.prefix_supersedes >= 1
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Fallback safety (version-vector check)
# ---------------------------------------------------------------------------

def test_stats_changing_append_falls_back_to_full(assert_tables_equal):
    rng = np.random.RandomState(9)
    full = _table(x=rng.randint(0, 9, 80).astype(np.float32),
                  k=rng.randint(0, 3, 80).astype(np.int32))
    base = _sub(full, 0, 64)
    store = ModelStore()
    store.register_table("t", base, partition_rows=8)
    svc = PredictionService(store, execution_config=ExecutionConfig(
        sharded=True, shard_min_bucket_rows=4, shard_morsel_rows=16))
    sql = "SELECT k, SUM(x) AS s FROM t GROUP BY k"
    try:
        svc.run(sql)
        out_of_domain = _table(x=np.asarray([500.0] * 8, np.float32),
                               k=np.asarray([1] * 8, np.int32))
        store.append_rows("t", out_of_domain)  # max(x) grows -> 'table'
        cur = base.concat_rows(out_of_domain)
        out = svc.run(sql)
        assert svc.stats.delta_serves == 0
        ref_store = ModelStore()
        ref_store.register_table("t", cur, partition_rows=8)
        ref_svc = PredictionService(ref_store)
        try:
            assert_tables_equal(out, ref_svc.run(sql))
        finally:
            ref_svc.close()
    finally:
        svc.close()


def test_mid_flight_append_serves_current_rows(assert_tables_equal):
    # A plan compiled before the append holds pre-append partition
    # metadata; the per-serve version check must re-resolve partitions so
    # the appended rows are scanned (never silently dropped).
    full = _table(x=np.arange(96, dtype=np.float32),
                  k=(np.arange(96) % 3).astype(np.int32))
    base = _sub(full, 0, 64)
    store = ModelStore()
    store.register_table("t", base, partition_rows=8)
    svc = PredictionService(store, execution_config=ExecutionConfig(
        sharded=True, shard_min_bucket_rows=4, shard_morsel_rows=16))
    sql = "SELECT k, SUM(x) AS s FROM t GROUP BY k"
    try:
        svc.run(sql)
        batch = _sub(full, 64, 96)  # out-of-domain: x extends past base max
        store.append_rows("t", batch)
        cur = base.concat_rows(batch)
        out = svc.run(sql)
        ref_store = ModelStore()
        ref_store.register_table("t", cur, partition_rows=8)
        ref_svc = PredictionService(ref_store)
        try:
            assert_tables_equal(out, ref_svc.run(sql))
        finally:
            ref_svc.close()
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Freshness SLA (max_staleness_s) under the fake clock
# ---------------------------------------------------------------------------

def test_request_level_staleness_sla(ingest, assert_tables_equal):
    full, base, pipe = ingest
    clock = ManualClock()
    store, svc = _service(base, pipe, clock=clock)
    try:
        pre = svc.run(SQL)
        store.append_rows("patient_info", _sub(full, 0, 40))
        clock.advance(1.0)
        within = svc.run(SQL, max_staleness_s=5.0)
        assert svc.stats.stale_serves == 1
        assert_tables_equal(within, pre)     # pre-append snapshot, bitwise
        clock.advance(10.0)
        lapsed = svc.run(SQL, max_staleness_s=5.0)
        assert svc.stats.stale_serves == 1   # budget lapsed: no stale serve
        assert lapsed.capacity == 540
    finally:
        svc.close()


def test_per_tenant_staleness_sla(ingest, assert_tables_equal):
    full, base, pipe = ingest
    clock = ManualClock()
    store, svc = _service(
        base, pipe, clock=clock,
        tenants={"analytics": TenantPolicy(max_staleness_s=30.0)})
    try:
        lax = svc.session(tenant="analytics")
        pre = lax.sql(SQL)
        store.append_rows("patient_info", _sub(full, 0, 40))
        clock.advance(5.0)
        # tenant policy allows the pre-append snapshot within its SLA ...
        assert_tables_equal(lax.sql(SQL), pre)
        assert svc.stats.stale_serves == 1
        # ... while a tenant without a policy always sees current rows
        live = svc.session()
        assert live.sql(SQL).capacity == 540
        # once the tenant SLA lapses, the stale tier closes for it too
        clock.advance(26.0)                  # 31s since append > 30s SLA
        assert lax.sql(SQL).capacity == 540
    finally:
        svc.close()
