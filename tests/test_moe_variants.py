"""MoE implementation equivalence: local, psum-EP, a2a-EP (1-device mesh;
collectives degenerate but the full dispatch code path executes)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced_config
from repro.launch.mesh import make_local_mesh
from repro.models.layers import init_params
from repro.models.moe import (moe_apply, moe_apply_sharded,
                              moe_apply_sharded_a2a, moe_params,
                              moe_reference)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-moe-30b-a3b"))
    p = init_params(moe_params(cfg), jax.random.PRNGKey(0))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    ref = moe_reference(cfg, p, x)
    return cfg, p, x, ref


def test_local_matches_reference(setup):
    cfg, p, x, ref = setup
    got = moe_apply(cfg, p, x, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


def test_psum_ep_matches_reference(setup):
    cfg, p, x, ref = setup
    mesh = make_local_mesh(1, 1)
    got = moe_apply_sharded(cfg, p, x, mesh, ("data",),
                            capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


def test_a2a_ep_matches_reference(setup):
    cfg, p, x, ref = setup
    mesh = make_local_mesh(1, 1)
    got = moe_apply_sharded_a2a(cfg, p, x, mesh, ("data",),
                                capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


def test_capacity_drops_lowest_gates(setup):
    """With capacity 1, each expert keeps only its highest-gate token —
    dropped tokens lose that expert's contribution but keep others."""
    cfg, p, x, ref = setup
    tight = moe_apply(cfg, p, x, capacity_factor=0.01)   # cap -> 1
    # must stay finite and bounded by the reference's magnitude scale
    t = np.asarray(tight)
    assert np.isfinite(t).all()
    assert np.abs(t).max() <= np.abs(np.asarray(ref)).max() * 5 + 1.0
