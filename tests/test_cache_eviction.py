"""Cost-aware eviction policy + invalidation hooks.

Covers the serving layer's shared eviction policy in isolation
(`repro.serve.cache.CostAwareCache`) and wired into `PredictionService`:

- bytes budget respected after *every* insert (including an entry larger
  than the whole budget);
- cost-weighted victim selection beats plain LRU on a synthetic skewed
  workload (an expensive hot entry survives a stream of cheap one-shots);
- `ModelStore.register_model` invalidation evicts exactly the entries
  referencing that model name, with hit/miss counters asserted before and
  after.
"""

import numpy as np
import pytest

from repro.core import ModelStore, OptimizerConfig
from repro.data import hospital_tables
from repro.ml import DecisionTree, Pipeline, PipelineMetadata, StandardScaler
from repro.serve import PredictionService
from repro.serve.cache import CostAwareCache, value_nbytes

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------------------
# CostAwareCache in isolation
# ---------------------------------------------------------------------------

def test_bytes_budget_respected_after_every_insert():
    cache = CostAwareCache(max_entries=100, max_bytes=1000)
    rng = np.random.default_rng(0)
    for i in range(60):
        nbytes = int(rng.integers(1, 400))
        cache.put(f"k{i}", object(), cost_s=float(rng.random()),
                  nbytes=nbytes)
        assert cache.bytes_in_use <= 1000, \
            f"over budget after insert {i}: {cache.bytes_in_use}"
        assert len(cache) <= 100
    assert cache.evictions > 0


def test_same_key_overwrite_does_not_double_count_bytes():
    """Regression: re-inserting an existing key must replace its byte
    charge, not add a second one — under a tight budget a double-counted
    overwrite would blow ``bytes_in_use`` past the budget and spuriously
    evict the entry (or an innocent bystander) on a no-op refresh."""
    cache = CostAwareCache(max_entries=8, max_bytes=250)
    payload = np.zeros(25, np.float32)            # 100 bytes
    cache.put("a", payload, cost_s=1.0)
    cache.put("b", payload, cost_s=1.0)
    assert cache.bytes_in_use == 200
    for _ in range(5):                            # refreshes, same size
        evicted = cache.put("a", payload, cost_s=1.0)
        assert evicted == []
        assert cache.bytes_in_use == 200
    # size-changing overwrite: charge tracks the new payload exactly
    cache.put("a", np.zeros(10, np.float32), cost_s=1.0)    # 40 bytes
    assert cache.bytes_in_use == 140
    cache.put("a", payload, cost_s=1.0, nbytes=100)         # explicit nbytes
    assert cache.bytes_in_use == 200
    assert sorted(cache.keys()) == ["a", "b"]
    # the ledger always equals the sum of resident entries' charges
    assert cache.bytes_in_use == sum(
        cache.entry(k).nbytes for k in cache.keys())


def test_entry_larger_than_budget_never_retained():
    cache = CostAwareCache(max_entries=10, max_bytes=100)
    cache.put("small", 1, cost_s=1.0, nbytes=40)
    cache.put("huge", 2, cost_s=100.0, nbytes=1000)
    assert "huge" not in cache
    assert cache.bytes_in_use <= 100


def test_max_entries_zero_disables_caching():
    cache = CostAwareCache(max_entries=0)
    cache.put("k", 1, cost_s=1.0, nbytes=1)
    assert len(cache) == 0
    assert cache.get("k") is None


def test_nbytes_measured_from_arrays():
    from repro.relational.table import Table
    arr = np.zeros((10, 4), np.float32)
    assert value_nbytes(arr) == 160
    t = Table.from_arrays({"a": np.zeros(8, np.float32),
                           "b": np.zeros(8, np.int32)})
    assert value_nbytes(t) == 8 * 4 + 8 * 4 + 8   # cols + bool valid mask
    assert value_nbytes({"x": arr, "y": [arr]}) == 320


def test_eviction_keeps_costly_hot_entry():
    """Weight = cost x hits: a hot, expensive-to-rebuild entry must survive
    a stream of cheap one-shot entries even when it is the LRU victim."""
    cache = CostAwareCache(max_entries=3)
    cache.put("hot", "H", cost_s=1.0, nbytes=1)
    for _ in range(4):
        assert cache.get("hot") == "H"
    for i in range(20):
        cache.put(f"cheap{i}", i, cost_s=1e-3, nbytes=1)
        assert cache.get("hot") is not None or i < 2, \
            "cost-aware policy evicted the hot expensive entry"
    assert "hot" in cache


class _PlainLRU:
    """Reference LRU with the same budget semantics, for the shootout."""

    def __init__(self, max_entries):
        self.max_entries = max_entries
        self._order = []
        self._values = {}

    def get(self, key):
        if key not in self._values:
            return None
        self._order.remove(key)
        self._order.append(key)
        return self._values[key]

    def put(self, key, value, **_):
        if key in self._values:
            self._order.remove(key)
        self._order.append(key)
        self._values[key] = value
        while len(self._order) > self.max_entries:
            self._values.pop(self._order.pop(0))


def _replay(cache):
    """Skewed workload: one expensive entry re-read every 5th step, cheap
    one-shots streaming through a 3-slot cache in between."""
    recompiles = 0
    for step in range(100):
        if step % 5 == 0:
            if cache.get("expensive") is None:
                recompiles += 1              # simulate the costly rebuild
                cache.put("expensive", "E", cost_s=1.0, nbytes=1)
        cache.put(f"one_shot_{step}", step, cost_s=1e-3, nbytes=1)
    return recompiles


def test_cost_weighted_selection_beats_plain_lru():
    lru_recompiles = _replay(_PlainLRU(max_entries=3))
    cost_recompiles = _replay(CostAwareCache(max_entries=3))
    assert cost_recompiles == 1              # initial compile only
    assert lru_recompiles == 20              # evicted before every re-read
    assert cost_recompiles < lru_recompiles


def test_evict_by_tag_exact():
    cache = CostAwareCache(max_entries=10)
    cache.put("a1", 1, cost_s=1.0, nbytes=1, tags=(("model", "A"),))
    cache.put("a2", 2, cost_s=1.0, nbytes=1,
              tags=(("model", "A"), ("table", "t")))
    cache.put("b", 3, cost_s=1.0, nbytes=1, tags=(("model", "B"),))
    cache.put("plain", 4, cost_s=1.0, nbytes=1)
    evicted = cache.evict_by_tag(("model", "A"))
    assert sorted(evicted) == ["a1", "a2"]
    assert "b" in cache and "plain" in cache


# ---------------------------------------------------------------------------
# Invalidation wired through ModelStore -> PredictionService
# ---------------------------------------------------------------------------

FEATS = ["age", "gender", "pregnant", "rcount"]
SQL_A = "SELECT pid, PREDICT(MODEL='model_a') AS p FROM patient_info"
SQL_B = "SELECT pid, PREDICT(MODEL='model_b') AS p FROM patient_info"


def _pipeline(data, name, depth):
    sc = StandardScaler(FEATS).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=depth),
                    PipelineMetadata(name=name, task="regression"))
    pipe.fit({k: data[k] for k in FEATS}, data["length_of_stay"])
    return pipe


def _service(store, **kwargs):
    # Small trees would inline to relational CASE ops, leaving no inference
    # subtree to capture; keep predict_model nodes intact so these tests
    # exercise the result-cache tier deterministically.
    return PredictionService(
        store, optimizer_config=OptimizerConfig(enable_model_inlining=False),
        **kwargs)


@pytest.fixture()
def two_model_store():
    store = ModelStore()
    for n, t in hospital_tables(300, seed=11).items():
        store.register_table(n, t)
    pi = store.get_table("patient_info")
    data = {c: np.asarray(pi.column(c)) for c in pi.names}
    store.register_model("model_a", _pipeline(data, "model_a", 4))
    store.register_model("model_b", _pipeline(data, "model_b", 5))
    return store


def test_register_model_evicts_exactly_referencing_entries(two_model_store):
    store = two_model_store
    svc = _service(store)
    svc.run(SQL_A)
    svc.run(SQL_B)
    assert svc.cache_info()["entries"] == 2
    assert svc.cache_info()["result_entries"] == 2
    assert (svc.stats.cache_hits, svc.stats.cache_misses) == (0, 2)

    # byte-identical re-registration: the content digest would still HIT —
    # only the invalidation hook can force the miss
    store.register_model("model_a", store.get_model("model_a"))

    info = svc.cache_info()
    assert info["entries"] == 1, "model_b entry must survive"
    assert info["result_entries"] == 1
    assert svc.stats.invalidation_evictions == 2   # one exec + one result

    svc.run(SQL_B)                     # untouched model still hits
    assert (svc.stats.cache_hits, svc.stats.cache_misses) == (1, 2)
    svc.run(SQL_A)                     # re-registered model must miss
    assert (svc.stats.cache_hits, svc.stats.cache_misses) == (1, 3)
    assert svc.cache_info()["entries"] == 2


def test_register_table_evicts_referencing_entries(two_model_store):
    store = two_model_store
    svc = _service(store)
    svc.run(SQL_A)
    assert svc.cache_info()["entries"] == 1
    store.register_table("patient_info", store.get_table("patient_info"))
    assert svc.cache_info()["entries"] == 0
    assert svc.cache_info()["result_entries"] == 0


def test_unrelated_registration_evicts_nothing(two_model_store):
    store = two_model_store
    svc = _service(store)
    svc.run(SQL_A)
    before = svc.cache_info()
    store.register_model("model_c",
                         _pipeline({c: np.asarray(
                             store.get_table("patient_info").column(c))
                             for c in store.get_table("patient_info").names},
                             "model_c", 3))
    store.register_table("blood_tests", store.get_table("blood_tests"))
    after = svc.cache_info()
    assert after["entries"] == before["entries"]
    assert after["result_entries"] == before["result_entries"]
    assert svc.stats.invalidation_evictions == 0
