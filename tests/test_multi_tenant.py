"""Multi-tenant front door: session/tenant context threaded end to end.

Pinned guarantees (ManualClock, no threads, no sleeps unless noted):

1. **Weighted drain order** — under contention, per-tenant queues drain in
   deficit-round-robin proportion to policy weights; a single-tenant load
   reduces exactly to the pre-tenant arrival order.
2. **Per-tenant backpressure** — a tenant at its ``max_queue`` is rejected
   (and ledgered) without touching its neighbors' admission.
3. **Quota isolation** — a flooding tenant churns only its own result-cache
   slice; an adversary cannot evict another tenant's entries past its
   quota.
4. **Parameterized plan reuse** — 100 distinct literal bindings of one SQL
   text produce zero warm compiles and one plan signature, with bit-exact
   results vs the literal-inlined query.
5. **Context-aware hooks** — ``on_admit``/``on_flush`` receive the request
   context; legacy lower-arity hooks keep working unmodified.
6. **Default-path neutrality** — ``tenant=None`` requests flow through the
   default queue with the old behavior and never appear in tenant ledgers.
7. **Deadline shedding** — once the queue-wait and per-key execution EWMAs
   are calibrated, a submit whose ``deadline_s`` is below their sum raises
   ``DeadlineUnmeetable`` instead of occupying queue space to miss anyway;
   cold keys (no estimate) never shed.
8. **Compile caps** — ``max_tenant_compiles`` releases at most that many
   *cold* (uncompiled-signature) groups per tenant per pass, so a
   signature-flooding tenant compiles serially in the background while a
   compliant tenant's warm traffic drains on schedule (p95 regression).
"""

import numpy as np
import pytest

from repro.core import ModelStore
from repro.core.codegen import add_compile_listener
from repro.core.ir import plan_signature
from repro.core.sql_frontend import parse_query
from repro.data import hospital_tables
from repro.ml import DecisionTree, Pipeline, PipelineMetadata, StandardScaler
from repro.relational.table import Table
from repro.serve import (AdmissionConfig, AdmissionQueueFull, Batcher,
                         CostAwareCache, DeadlineUnmeetable, ManualClock,
                         PredictionService, RequestContext, Session,
                         TenantPolicy)

pytestmark = pytest.mark.tier1

N_ROWS = 400
FEATS = ["age", "gender", "pregnant", "rcount"]
SQL_PARAM = ("SELECT pid, age, PREDICT(MODEL='m') AS p "
             "FROM patient_info WHERE age > :lo")


@pytest.fixture(scope="module")
def base():
    full = hospital_tables(N_ROWS, seed=7)["patient_info"]
    data = {c: np.asarray(full.column(c)) for c in full.names}
    sc = StandardScaler(FEATS).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=6),
                    PipelineMetadata(name="m", task="regression"))
    pipe.fit({k: data[k] for k in FEATS}, data["length_of_stay"])
    store = ModelStore()
    store.register_table("patient_info", full)
    store.register_model("m", pipe)
    return store, full, pipe


def _service(store, clock=None, tenants=None, jit=False,
             optimizer_config=None, **cfg):
    defaults = dict(latency_budget_s=1.0, background=False)
    defaults.update(cfg)
    return PredictionService(store, jit=jit, clock=clock or ManualClock(),
                             admission=AdmissionConfig(**defaults),
                             optimizer_config=optimizer_config,
                             tenants=tenants)


def _ctx(tenant, **kw):
    return RequestContext(tenant=tenant, **kw)


# ---------------------------------------------------------------------------
# 1. Weighted deficit-round-robin drain order
# ---------------------------------------------------------------------------

def test_weighted_drr_drain_order():
    policies = {"a": TenantPolicy(weight=2.0), "b": TenantPolicy(weight=1.0)}
    b = Batcher(AdmissionConfig(background=False), clock=ManualClock(),
                tenant_policies=policies)
    for i in range(4):
        b.offer(("a", i), f"a{i}", ctx=_ctx("a"))
    for i in range(2):
        b.offer(("b", i), f"b{i}", ctx=_ctx("b"))
    order = [g.ctx.tenant for g in b.drain()]
    assert order == ["a", "a", "b", "a", "a", "b"]


def test_equal_weights_alternate():
    b = Batcher(AdmissionConfig(background=False), clock=ManualClock(),
                tenant_policies={"a": TenantPolicy(), "b": TenantPolicy()})
    for i in range(3):
        b.offer(("a", i), f"a{i}", ctx=_ctx("a"))
        b.offer(("b", i), f"b{i}", ctx=_ctx("b"))
    order = [g.ctx.tenant for g in b.drain()]
    assert order == ["a", "b"] * 3


def test_single_tenant_keeps_arrival_order():
    """No contention -> DRR is bypassed entirely; groups release in the
    exact order a tenantless batcher would produce."""
    b = Batcher(AdmissionConfig(background=False), clock=ManualClock())
    for i in range(5):
        b.offer(("k", i), f"x{i}", ctx=_ctx("solo"))
    assert [g.items[0] for g in b.drain()] == [f"x{i}" for i in range(5)]


def test_default_tenant_cycles_first_at_equal_weight():
    """At equal weight the ``None`` (pre-tenant) queue sorts ahead of named
    tenants in each DRR cycle, so legacy traffic is never starved behind a
    same-weight named tenant."""
    b = Batcher(AdmissionConfig(background=False), clock=ManualClock(),
                tenant_policies={"a": TenantPolicy(weight=1.0)})
    b.offer(("a", 0), "named", ctx=_ctx("a"))
    b.offer(("k", 0), "legacy")
    assert [g.items[0] for g in b.drain()] == ["legacy", "named"]


def test_priority_breaks_ties_within_tenant():
    b = Batcher(AdmissionConfig(background=False), clock=ManualClock())
    b.offer(("k", 0), "low", ctx=_ctx("a", priority=0))
    b.offer(("k", 1), "high", ctx=_ctx("a", priority=5))
    assert [g.items[0] for g in b.drain()] == ["high", "low"]


def test_ctx_deadline_tightens_release():
    """A per-request deadline below the service budget releases the group
    at the request deadline, not the budget."""
    clock = ManualClock()
    b = Batcher(AdmissionConfig(latency_budget_s=10.0, background=False),
                clock=clock)
    b.offer(("k", 0), "urgent", ctx=_ctx("a", deadline_s=0.5))
    clock.advance(0.6)
    groups = b.pop_ready(clock.monotonic())
    assert [g.items[0] for g in groups] == ["urgent"]
    assert groups[0].reason == "deadline"


def test_ctx_deadline_cannot_loosen_budget():
    clock = ManualClock()
    b = Batcher(AdmissionConfig(latency_budget_s=0.5, background=False),
                clock=clock)
    b.offer(("k", 0), "lazy", ctx=_ctx("a", deadline_s=99.0))
    clock.advance(0.6)
    assert len(b.pop_ready(clock.monotonic())) == 1


# ---------------------------------------------------------------------------
# 2. Per-tenant backpressure
# ---------------------------------------------------------------------------

def test_per_tenant_backpressure_isolates_neighbors():
    policies = {"flood": TenantPolicy(max_queue=2)}
    b = Batcher(AdmissionConfig(background=False, block_on_full=False,
                                max_queue=100),
                clock=ManualClock(), tenant_policies=policies)
    b.offer(("k", 0), "f0", ctx=_ctx("flood"))
    b.offer(("k", 1), "f1", ctx=_ctx("flood"))
    with pytest.raises(AdmissionQueueFull, match="tenant 'flood'"):
        b.offer(("k", 2), "f2", ctx=_ctx("flood"))
    # neighbor and default traffic still admit
    b.offer(("k", 3), "ok", ctx=_ctx("calm"))
    b.offer(("k", 4), "legacy")
    assert b.rejections == {"flood": 1}
    assert b.depth("flood") == 2 and b.depth("calm") == 1


def test_global_bound_still_applies_across_tenants():
    b = Batcher(AdmissionConfig(background=False, block_on_full=False,
                                max_queue=2),
                clock=ManualClock())
    b.offer(("k", 0), "a0", ctx=_ctx("a"))
    b.offer(("k", 1), "b0", ctx=_ctx("b"))
    with pytest.raises(AdmissionQueueFull):
        b.offer(("k", 2), "c0", ctx=_ctx("c"))


def test_service_surfaces_tenant_rejections(base):
    store, _, _ = base
    svc = _service(store, tenants={"flood": TenantPolicy(max_queue=1)},
                   block_on_full=False, max_queue=100)
    s = svc.session(tenant="flood")
    s.submit(SQL_PARAM, params={"lo": 10})
    with pytest.raises(AdmissionQueueFull):
        s.submit(SQL_PARAM, params={"lo": 11})
    info = svc.tenant_info()["flood"]
    assert info["rejections"] == 1
    svc.flush()


# ---------------------------------------------------------------------------
# 3. Cache quota isolation
# ---------------------------------------------------------------------------

def test_adversary_cannot_evict_neighbor_past_quota():
    cache = CostAwareCache(max_entries=64)
    cache.set_tenant_quota("flood", max_entries=4)
    for i in range(3):
        cache.put(("victim", i), i, cost_s=1e-6, nbytes=8, tenant="victim")
    for i in range(50):
        cache.put(("flood", i), i, cost_s=10.0, nbytes=8, tenant="flood")
    assert all(("victim", i) in cache for i in range(3))
    assert cache.tenant_usage("flood")["entries"] == 4
    assert cache.tenant_usage("flood")["evictions"] == 46
    assert cache.tenant_usage("victim")["evictions"] == 0


def test_bytes_quota_evicts_own_lowest_weight():
    cache = CostAwareCache(max_entries=64)
    cache.set_tenant_quota("t", max_bytes=100)
    cache.put(("t", "cheap"), 0, cost_s=0.001, nbytes=60, tenant="t")
    cache.put(("t", "dear"), 1, cost_s=10.0, nbytes=60, tenant="t")
    assert ("t", "cheap") not in cache and ("t", "dear") in cache


def test_untenanted_entries_ignore_quotas():
    cache = CostAwareCache(max_entries=64)
    cache.set_tenant_quota("t", max_entries=1)
    for i in range(10):
        cache.put(("none", i), i, cost_s=1.0, nbytes=8)
    assert len(cache) == 10 and cache.evictions == 0


def test_service_result_cache_quota_isolation(base):
    """End to end: a flooding tenant with a tiny result-cache quota churns
    its own capture entries while a compliant tenant's stay resident."""
    store, _, _ = base
    from repro.core import OptimizerConfig
    svc = _service(store, tenants={
        "calm": TenantPolicy(),
        "flood": TenantPolicy(result_cache_entries=2),
    },  # keep predict_model ops so every literal yields a capture entry
        optimizer_config=OptimizerConfig(enable_model_inlining=False))
    calm = svc.session(tenant="calm")
    flood = svc.session(tenant="flood")
    # distinct literals -> distinct signatures -> distinct capture subtrees
    for v in (30, 40):
        calm.sql("SELECT pid, PREDICT(MODEL='m') AS p "
                 f"FROM patient_info WHERE age > {v}")
    calm_resident = svc._result_cache.tenant_usage("calm")["entries"]
    assert calm_resident == 2
    for v in range(10, 22):
        flood.sql("SELECT pid, PREDICT(MODEL='m') AS p "
                  f"FROM patient_info WHERE age > {v}")
    usage = svc.tenant_info()
    assert usage["flood"]["result_cache_entries"] <= 2
    assert usage["flood"]["result_cache_evictions"] >= 10
    assert usage["calm"]["result_cache_entries"] == calm_resident
    assert usage["calm"]["result_cache_evictions"] == 0


# ---------------------------------------------------------------------------
# 4. Parameterized plan reuse
# ---------------------------------------------------------------------------

class _NoCatalog:
    """Catalog without schema: parser skips name resolution."""

    def get_model(self, name):
        raise KeyError(name)


def test_param_literals_share_one_signature():
    plan_a = parse_query("SELECT pid FROM t WHERE age > :lo", _NoCatalog())
    plan_b = parse_query("SELECT pid FROM t WHERE age > :lo", _NoCatalog())
    assert plan_signature(plan_a) == plan_signature(plan_b)


def test_zero_warm_compiles_across_100_literals(base):
    store, _, _ = base
    svc = _service(store)
    compiles = []
    unsub = add_compile_listener(lambda plan: compiles.append(1))
    try:
        svc.sql(SQL_PARAM, params={"lo": 0})       # cold: compiles once
        cold = len(compiles)
        assert cold >= 1
        outs = [svc.sql(SQL_PARAM, params={"lo": v}) for v in range(100)]
        assert len(compiles) == cold, "warm compiles across literals"
    finally:
        unsub()
    # and the results actually track the binding: identical surviving rows
    # vs the literal-inlined query (only valid rows are the result —
    # literal plans may optimize differently on pad/garbage rows)
    for v in (0, 37, 99):
        lit = svc.run("SELECT pid, age, PREDICT(MODEL='m') AS p "
                      f"FROM patient_info WHERE age > {v}")
        par = outs[v]
        lv, pv = np.asarray(lit.valid), np.asarray(par.valid)
        assert np.array_equal(lv, pv)
        for k in lit.columns:
            assert np.array_equal(np.asarray(lit.column(k))[lv],
                                  np.asarray(par.column(k))[pv]), k
    assert svc.stats.sql_parse_hits >= 100


def test_positional_and_named_params(base):
    store, _, _ = base
    svc = _service(store)
    named = svc.sql(SQL_PARAM, params={"lo": 42})
    positional = svc.sql("SELECT pid, age, PREDICT(MODEL='m') AS p "
                         "FROM patient_info WHERE age > ?", params=[42])
    assert np.array_equal(np.asarray(named.valid),
                          np.asarray(positional.valid))


def test_missing_param_fails_ticket(base):
    store, _, _ = base
    svc = _service(store)
    ticket = svc.submit(SQL_PARAM)           # no binding supplied
    with pytest.raises(ValueError, match="lo"):
        ticket.result(timeout=1.0)


def test_distinct_bindings_never_coalesce(base):
    """Same plan, different bindings: one executable, separate executions
    (their outputs differ), and each ticket gets its own binding's rows."""
    store, _, _ = base
    svc = _service(store)
    svc.sql(SQL_PARAM, params={"lo": 0})     # warm the executable
    t1 = svc.submit(SQL_PARAM, params={"lo": 30})
    t2 = svc.submit(SQL_PARAM, params={"lo": 60})
    before = svc.stats.batch_executions
    svc.flush()
    assert svc.stats.batch_executions == before + 2
    v1 = int(np.asarray(t1.result().valid).sum())
    v2 = int(np.asarray(t2.result().valid).sum())
    assert v1 > v2


def test_identical_bindings_coalesce(base):
    store, _, _ = base
    svc = _service(store)
    svc.sql(SQL_PARAM, params={"lo": 30})
    tickets = [svc.submit(SQL_PARAM, params={"lo": 30}) for _ in range(3)]
    before = svc.stats.batch_executions
    svc.flush()
    assert svc.stats.batch_executions == before + 1
    outs = [t.result() for t in tickets]
    for o in outs[1:]:
        assert np.array_equal(np.asarray(o.valid), np.asarray(outs[0].valid))


# ---------------------------------------------------------------------------
# 5. Context-aware hooks
# ---------------------------------------------------------------------------

def test_hooks_receive_context():
    b = Batcher(AdmissionConfig(background=False), clock=ManualClock())
    admits, flushes = [], []
    b.on_admit = lambda item, ctx: admits.append((item, ctx))
    b.on_flush = lambda key, items, reason, ctx: flushes.append(
        (key, tuple(items), reason, ctx))
    ctx = _ctx("a", priority=3)
    b.offer("k", "item", ctx=ctx)
    b.drain()
    assert admits == [("item", ctx)]
    assert flushes == [("k", ("item",), "drain", ctx)]


def test_legacy_hooks_unchanged():
    """Pre-tenant hook arities (1-arg admit, 3-arg flush) — including
    builtins like ``list.append`` — keep working with no adapter."""
    b = Batcher(AdmissionConfig(background=False), clock=ManualClock())
    admits, flushes = [], []
    b.on_admit = admits.append
    b.on_flush = lambda key, items, reason: flushes.append((key, reason))
    b.offer("k", "item", ctx=_ctx("a"))
    b.drain()
    assert admits == ["item"]
    assert flushes == [("k", "drain")]


# ---------------------------------------------------------------------------
# 6. Ledgers and default-path neutrality
# ---------------------------------------------------------------------------

def test_tenant_info_latencies_from_fake_clock(base):
    store, _, _ = base
    clock = ManualClock()
    svc = _service(store, clock=clock, latency_budget_s=5.0)
    s = svc.session(tenant="acme")
    s.sql(SQL_PARAM, params={"lo": 30})      # warm (flush at t=0)
    s.submit(SQL_PARAM, params={"lo": 31})
    clock.advance(2.0)
    svc.admission_tick(force=True)
    info = svc.tenant_info()["acme"]
    assert info["queue_p95_ms"] == pytest.approx(2000.0)
    assert info["submitted"] == 2 and info["served"] == 2


def test_sessions_share_tenant_ledger(base):
    store, _, _ = base
    svc = _service(store)
    s1 = svc.session(tenant="acme")
    s2 = svc.session(tenant="acme")
    assert s1.ctx.session != s2.ctx.session
    s1.sql(SQL_PARAM, params={"lo": 30})
    s2.sql(SQL_PARAM, params={"lo": 31})
    assert svc.tenant_info()["acme"]["submitted"] == 2


def test_default_path_absent_from_tenant_ledger(base):
    store, _, _ = base
    svc = _service(store)
    svc.run("SELECT pid FROM patient_info WHERE age > 50")
    assert svc.tenant_info() == {}
    assert svc.batcher.depths() in ({}, {None: 0})


def test_tenant_path_bit_exact_vs_default(base, assert_tables_equal):
    store, _, _ = base
    svc = _service(store)
    plain = svc.run("SELECT pid, PREDICT(MODEL='m') AS p "
                    "FROM patient_info WHERE age > 30")
    tenant = svc.session(tenant="acme").sql(
        "SELECT pid, PREDICT(MODEL='m') AS p "
        "FROM patient_info WHERE age > 30")
    assert_tables_equal(plain, tenant)


def test_register_tenant_applies_immediately(base):
    store, _, _ = base
    svc = _service(store, block_on_full=False, max_queue=100)
    svc.register_tenant("late", TenantPolicy(max_queue=1))
    s = svc.session(tenant="late")
    s.submit(SQL_PARAM, params={"lo": 1})
    with pytest.raises(AdmissionQueueFull):
        s.submit(SQL_PARAM, params={"lo": 2})
    svc.flush()


# ---------------------------------------------------------------------------
# 7. Deadline-based shedding
# ---------------------------------------------------------------------------

def test_deadline_unmeetable_sheds_at_submit(base):
    store, _, _ = base
    clock = ManualClock()
    svc = _service(store, clock=clock, latency_budget_s=5.0)
    s = svc.session(tenant="acme")
    s.sql(SQL_PARAM, params={"lo": 30})      # warm: exec EWMA calibrated
    s.submit(SQL_PARAM, params={"lo": 30})
    clock.advance(2.0)
    svc.admission_tick(force=True)           # queue-wait EWMA -> 0.4s
    with pytest.raises(DeadlineUnmeetable, match="unmeetable"):
        svc.submit(SQL_PARAM, params={"lo": 30}, tenant="acme",
                   deadline_s=0.05)
    assert svc.stats.deadline_rejections == 1
    assert svc.admission_info()["deadline_rejections"] == 1
    assert svc.tenant_info()["acme"]["deadline_rejections"] == 1
    # a meetable deadline still admits and serves normally
    t = svc.submit(SQL_PARAM, params={"lo": 30}, tenant="acme",
                   deadline_s=10.0)
    svc.flush()
    assert t.result(timeout=5.0) is not None
    assert svc.stats.deadline_rejections == 1


def test_cold_keys_never_shed(base):
    """No execution estimate for a never-compiled signature -> admit (the
    shed must not block first-time traffic however tight the deadline)."""
    store, _, _ = base
    clock = ManualClock()
    svc = _service(store, clock=clock, latency_budget_s=5.0)
    s = svc.session(tenant="acme")
    s.sql(SQL_PARAM, params={"lo": 30})
    s.submit(SQL_PARAM, params={"lo": 31})
    clock.advance(4.0)
    svc.admission_tick(force=True)           # queue-wait EWMA calibrated
    t = svc.submit("SELECT pid, age FROM patient_info WHERE age > 77",
                   tenant="acme", deadline_s=1e-6)
    svc.flush()
    assert t.result(timeout=5.0) is not None
    assert svc.stats.deadline_rejections == 0


# ---------------------------------------------------------------------------
# 8. Per-tenant compile caps
# ---------------------------------------------------------------------------

def test_compile_cap_defers_cold_groups():
    clock = ManualClock()
    b = Batcher(AdmissionConfig(background=False, latency_budget_s=1.0,
                                max_tenant_compiles=1), clock=clock)
    b.is_cold = lambda key: key != "warm"
    for i in range(3):
        b.offer(("cold", i), f"c{i}", ctx=_ctx("flood"))
    b.offer("warm", "w", ctx=_ctx("flood"))
    clock.advance(2.0)
    released = [g.items[0] for g in b.pop_ready(clock.monotonic())]
    # one cold group + every warm group release; other colds stay queued
    assert "w" in released
    assert sum(1 for x in released if x.startswith("c")) == 1
    assert b.compile_deferrals == 2
    # the next pass releases the next cold group: deferral, not starvation
    second = [g.items[0] for g in b.pop_ready(clock.monotonic())]
    assert sum(1 for x in second if x.startswith("c")) == 1
    assert b.compile_deferrals == 3
    # drain (force) bypasses the cap and takes the tail
    assert len(b.drain()) == 1


def test_compile_cap_is_per_tenant():
    clock = ManualClock()
    b = Batcher(AdmissionConfig(background=False, latency_budget_s=1.0,
                                max_tenant_compiles=1), clock=clock)
    b.is_cold = lambda key: True
    for t in ("a", "b"):
        for i in range(2):
            b.offer((t, i), f"{t}{i}", ctx=_ctx(t))
    clock.advance(2.0)
    released = [g.items[0] for g in b.pop_ready(clock.monotonic())]
    assert sorted(released) == ["a0", "b0"]      # one cold budget each
    assert b.compile_deferrals == 2


def test_compile_cap_shields_compliant_tenant_p95(base):
    """Regression for the admission bug the cap fixes: a tenant flooding
    unique plan signatures used to stack its compiles in front of a
    compliant tenant's warm traffic, inflating the compliant p95.  Compile
    wall time is simulated by advancing the ManualClock from a compile
    listener, so the comparison is deterministic."""
    store, _, _ = base
    flood_sql = [f"SELECT pid FROM patient_info WHERE age > {40 + i}"
                 for i in range(6)]

    def run_scenario(max_tenant_compiles):
        clock = ManualClock()
        svc = _service(store, clock=clock, latency_budget_s=1.0,
                       max_tenant_compiles=max_tenant_compiles)
        svc.run(SQL_PARAM, params={"lo": 0})     # warm the compliant key
        unsub = add_compile_listener(lambda plan: clock.advance(1.0))
        try:
            flood = svc.session(tenant="flood")
            calm = svc.session(tenant="compliant")
            tickets = [flood.submit(q) for q in flood_sql]
            tickets += [calm.submit(SQL_PARAM, params={"lo": 30 + i})
                        for i in range(6)]
            clock.advance(2.0)
            svc.admission_tick()                 # non-forced: cap applies
            while any(not t.done for t in tickets):
                clock.advance(2.0)
                svc.admission_tick()             # deferred colds drain
            for t in tickets:
                assert t.result(timeout=5.0) is not None
            info = svc.tenant_info()
            return (info["compliant"]["queue_p95_ms"],
                    svc.admission_info()["compile_deferrals"])
        finally:
            unsub()

    p95_uncapped, deferrals_uncapped = run_scenario(0)
    p95_capped, deferrals_capped = run_scenario(1)
    assert deferrals_uncapped == 0 and deferrals_capped > 0
    # compliant warm traffic no longer waits behind the flood's compiles
    assert p95_capped < p95_uncapped
