"""Vocab-restricted decoding + the LLM-in-an-inference-query path
(the bridge between the paper's PREDICT and the LM serving substrate)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced_config
from repro.models import build_model
from repro.serve import InferenceEngine, Request, ServeConfig
from repro.serve.sampling import restrict_vocab, sample_token


def test_restrict_vocab_masks():
    logits = jnp.asarray([[1.0, 5.0, 3.0, 4.0]])
    tok = sample_token(logits, 0.0, None, allowed=(0, 2))
    assert int(tok[0]) == 2       # best allowed, not global argmax (1)


def test_restricted_sampling_never_leaves_set():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (8, 100))
    allowed = (3, 7, 42)
    for i in range(5):
        key, sub = jax.random.split(key)
        toks = sample_token(logits, 1.0, sub, allowed=allowed)
        assert set(np.asarray(toks).tolist()) <= set(allowed)


def test_engine_vocab_restricted_request():
    cfg = reduced_config(get_config("gemma2-2b"))
    model = build_model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, ServeConfig(n_slots=1, max_len=32,
                                             eos_token=-1))
    allowed = (10, 11, 12)
    eng.submit(Request(rid=0,
                       prompt=np.arange(5, dtype=np.int32),
                       max_new_tokens=4, allowed_tokens=allowed))
    eng.run_until_drained(params)
    out = eng.completed[0].output
    assert len(out) == 4
    assert set(out) <= set(allowed)
