"""Training-loop integration: loss drops, restart mid-run is exact,
grad accumulation is batch-equivalent, compression hooks in."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ShapeConfig, get_config, reduced_config
from repro.data.lm_data import TokenStream
from repro.distributed.compression import compress_tree
from repro.distributed.fault_tolerance import FailureInjector
from repro.models import build_model
from repro.train.loop import TrainLoopConfig, train
from repro.train.optimizer import AdamWConfig
from repro.train.train_state import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config(get_config("minicpm-2b"))
    return cfg, build_model(cfg, remat=False)


def test_loss_drops_and_restart_exact(tiny, tmp_path):
    cfg, model = tiny
    shape = ShapeConfig("t", "train", 24, 4)
    loop = TrainLoopConfig(n_steps=14, ckpt_root=str(tmp_path / "a"),
                           ckpt_every=5, log_every=7,
                           opt=AdamWConfig(peak_lr=3e-3, warmup_steps=3,
                                           total_steps=14))
    clean = train(model, shape, loop)
    assert clean["restarts"] == 0
    l0, l1 = clean["losses"][0][1], clean["losses"][-1][1]
    assert l1 < l0

    loop2 = TrainLoopConfig(n_steps=14, ckpt_root=str(tmp_path / "b"),
                            ckpt_every=5, log_every=7,
                            opt=loop.opt)
    crashy = train(model, shape, loop2,
                   injector=FailureInjector(fail_at=8))
    assert crashy["restarts"] == 1
    # determinism across the crash: identical final loss
    assert abs(clean["losses"][-1][1] - crashy["losses"][-1][1]) < 1e-4


def test_grad_accum_equivalent(tiny):
    cfg, model = tiny
    stream = TokenStream(cfg.vocab_size, 16, 4, seed=1)
    batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
    opt = AdamWConfig(peak_lr=1e-3)
    state1 = init_train_state(model, jax.random.PRNGKey(0))
    state2 = jax.tree_util.tree_map(lambda x: x, state1)
    s1, m1 = make_train_step(model, opt, grad_accum=1)(state1, batch)
    s2, m2 = make_train_step(model, opt, grad_accum=2)(state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                    jax.tree_util.tree_leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_nan_batch_skipped(tiny):
    cfg, model = tiny
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = make_train_step(model, AdamWConfig(peak_lr=1e-3))
    bad = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    # poison the params' embed so loss is NaN
    poisoned = dict(state)
    poisoned["params"] = dict(state["params"])
    poisoned["params"]["embed"] = state["params"]["embed"] * jnp.nan
    new_state, metrics = step(poisoned, bad)
    assert int(metrics["skipped"]) == 1
    # params unchanged (the skip kept old values)
    np.testing.assert_array_equal(
        np.asarray(new_state["params"]["final_norm"]),
        np.asarray(poisoned["params"]["final_norm"]))


def test_compression_hook_runs(tiny):
    cfg, model = tiny
    state = init_train_state(model, jax.random.PRNGKey(0))
    stream = TokenStream(cfg.vocab_size, 16, 2, seed=2)
    batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
    step = make_train_step(model, AdamWConfig(peak_lr=1e-3),
                           compress_grads=compress_tree)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(metrics["skipped"]) == 0
