"""Tour of every cross-optimization (paper §4), one by one.

For each rule: a query that triggers it, the before/after plans, the
semantic-equivalence check, and the measured effect.  This is the living
documentation of the optimizer.

Run:  PYTHONPATH=src python examples/optimizer_tour.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (CrossOptimizer, ModelStore, OptimizerConfig, execute,
                        parse_query)
from repro.core.clustering import build_clustered_model
from repro.data import flight_features, hospital_tables
from repro.ml import (DecisionTree, LogisticRegression, OneHotEncoder,
                      Pipeline, PipelineMetadata, StandardScaler)
from repro.relational import Table


def setup():
    store = ModelStore()
    tables = hospital_tables(20_000)
    for n, t in tables.items():
        store.register_table(n, t)
    data = {}
    for t in tables.values():
        for c in t.names:
            data[c] = np.asarray(t.column(c))
    feat = ["age", "gender", "pregnant", "rcount", "bp"]
    sc = StandardScaler(feat).fit(data)
    tree = Pipeline([sc], DecisionTree(task="regression", max_depth=7,
                                       min_leaf=20),
                    PipelineMetadata(name="los", task="regression"))
    tree.fit({k: data[k] for k in feat}, data["length_of_stay"])
    store.register_model("los", tree)

    fcols, fy = flight_features(20_000)
    ohe = OneHotEncoder(["origin", "dest", "carrier"]).fit(fcols)
    sc2 = StandardScaler(["distance", "taxi_out", "dep_hour"]).fit(fcols)
    lr = Pipeline([ohe, sc2], LogisticRegression(l1=0.02, steps=250),
                  PipelineMetadata(name="delay", task="classification"))
    lr.fit(fcols, fy)
    store.register_table("flights", Table.from_pydict(
        {**{k: v for k, v in fcols.items()}, "delayed": fy}))
    store.register_model("delay", lr)
    return store, tree, lr, fcols


def show(store, sql, cfg, title):
    print(f"\n=== {title} ===")
    plan = parse_query(sql, store)
    oplan, report = CrossOptimizer(store, cfg).optimize(plan)
    print(report.pretty())
    a = execute(plan, store).to_pydict()
    b = execute(oplan, store).to_pydict()
    key = next(iter(a))
    assert len(a[key]) == len(b[key]), "row count changed!"
    print(f"semantics preserved: {len(a[key])} rows")
    return report


def main():
    store, tree_pipe, lr_pipe, fcols = setup()

    show(store,
         "SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
         "JOIN blood_tests ON pid WHERE pregnant = 1 AND age > 30",
         OptimizerConfig(enable_nn_translation=False,
                         enable_model_inlining=False),
         "predicate-based model pruning (data->model)")

    show(store,
         "SELECT origin, PREDICT_PROBA(MODEL='delay') AS p FROM flights "
         "WHERE dest = 7",
         OptimizerConfig(enable_model_inlining=False,
                         enable_nn_translation=False),
         "one-hot constant folding + model-projection pushdown")

    show(store,
         "SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
         "JOIN blood_tests ON pid JOIN prenatal_tests ON pid",
         OptimizerConfig(),
         "join elimination (model uses no prenatal features)")

    show(store,
         "SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
         "JOIN blood_tests ON pid WHERE rcount > 2",
         OptimizerConfig(inline_max_nodes=1024,
                         enable_nn_translation=False),
         "model inlining (tree -> CASE WHEN)")

    show(store,
         "SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
         "JOIN blood_tests ON pid",
         OptimizerConfig(enable_model_inlining=False,
                         nn_translate_single_trees="always"),
         "NN translation (tree -> tree_gemm LA operator; forced on CPU — "
         "the cost-based default keeps traversal here, see cost_model.py)")

    show(store,
         "SELECT pid, PREDICT(MODEL='los') AS los FROM patient_info "
         "JOIN blood_tests ON pid WHERE age > 44",
         OptimizerConfig(enable_model_query_splitting=True,
                         enable_model_inlining=False,
                         enable_nn_translation=False,
                         split_imbalance=0.95),
         "model/query splitting (root-predicate cascade)")

    print("\n=== model clustering (offline precompile, Fig 2b) ===")
    cm = build_clustered_model(lr_pipe, {k: v[:4000] for k, v in
                                         fcols.items()}, k=4,
                               cluster_columns=["origin", "dest", "carrier"])
    print("cluster model cost:", cm.model_cost())
    full = np.asarray(lr_pipe.predict(
        {k: jnp.asarray(v) for k, v in fcols.items()}))
    routed = cm.predict_routed({k: jnp.asarray(v) for k, v in fcols.items()})
    print(f"routed agreement with full model: {(full == routed).mean():.4f}")


if __name__ == "__main__":
    main()
