"""LLM-in-an-inference-query: the bridge between the paper's PREDICT and
the LM serving substrate.

A table of tokenized support tickets is scored by a (reduced-config) LM with
*vocab-restricted decoding* — the projection-pushdown analogue: the query
only consumes two candidate tokens, so decoding is projected onto them —
and the predictions flow back into the relational engine for a GROUP BY.

Run:  PYTHONPATH=src python examples/llm_inference_query.py
"""

import numpy as np
import jax

from repro.configs import get_config, reduced_config
from repro.core import ModelStore, execute, parse_query
from repro.models import build_model
from repro.relational import Table
from repro.serve import InferenceEngine, Request, ServeConfig

YES_TOK, NO_TOK = 7, 11      # candidate set the query consumes


def main(n_tickets: int = 12):
    cfg = reduced_config(get_config("qwen2.5-14b"))
    model = build_model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # tokenized tickets (synthetic) + metadata table
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(n_tickets)]
    region = rng.integers(0, 3, n_tickets).astype(np.int32)

    # LM scoring pass: vocab-restricted single-token classification
    engine = InferenceEngine(model, ServeConfig(n_slots=4, max_len=24,
                                                eos_token=-1))
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=1,
                              allowed_tokens=(YES_TOK, NO_TOK)))
    engine.run_until_drained(params)
    escalate = np.zeros(n_tickets, np.int32)
    for r in engine.completed:
        escalate[r.rid] = 1 if r.output[0] == YES_TOK else 0

    # predictions land in the relational engine like any other column
    store = ModelStore()
    store.register_table("tickets", Table.from_pydict({
        "tid": np.arange(n_tickets, dtype=np.int32),
        "region": region,
        "escalate": escalate,
    }))
    plan = parse_query(
        "SELECT region, COUNT(*) AS n, SUM(escalate) AS esc "
        "FROM tickets GROUP BY region", store)
    out = execute(plan, store).to_pydict()
    print("region  tickets  escalations")
    for r, n, e in zip(out["region"], out["n"], out["esc"]):
        print(f"  {r}      {int(n):5d}    {e:6.0f}")
    total = sum(out["esc"])
    print(f"\n{int(total)}/{n_tickets} tickets escalated by the LM "
          f"(vocab-restricted to {{{YES_TOK},{NO_TOK}}})")


if __name__ == "__main__":
    main()
