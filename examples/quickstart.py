"""Quickstart: the paper's running example, end to end (Fig 1).

Trains the length-of-stay model, stores it (versioned, audited) in the
in-DB model store, then runs the inference query

    SELECT pid, age, PREDICT(MODEL='los_model') AS los
    FROM patient_info JOIN blood_tests ON pid JOIN prenatal_tests ON pid
    WHERE pregnant = 1 AND PREDICT(MODEL='los_model') > 7

unoptimized and cross-optimized, verifies identical results, and prints the
optimizer report + timings.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CrossOptimizer, ModelStore, OptimizerConfig,
                        compile_plan, execute, parse_query)
from repro.data import hospital_tables
from repro.ml import (DecisionTree, Pipeline, PipelineMetadata,
                      StandardScaler)


def main(n_rows: int = 50_000):
    print(f"== setup: {n_rows} synthetic patients ==")
    store = ModelStore(principal="quickstart")
    tables = hospital_tables(n_rows)
    for name, t in tables.items():
        store.register_table(name, t)

    # train + deploy the model pipeline (transactional registration)
    feat_cols = ["age", "gender", "pregnant", "rcount", "hematocrit",
                 "neutrophils", "bp"]
    data = {}
    for t in tables.values():
        for c in t.names:
            data[c] = np.asarray(t.column(c))
    scaler = StandardScaler(feat_cols).fit(data)
    pipe = Pipeline([scaler],
                    DecisionTree(task="regression", max_depth=8, min_leaf=20),
                    PipelineMetadata(name="los_model", task="regression",
                                     signature_inputs=tuple(feat_cols)))
    pipe.fit({k: data[k] for k in feat_cols}, data["length_of_stay"])
    with store.transaction() as txn:
        txn.register("los_model", pipe)
    print(f"model registered (version {store.model_version('los_model')}, "
          f"{pipe.model.tree.n_nodes} tree nodes)")

    sql = """
    SELECT pid, age, PREDICT(MODEL='los_model') AS los
    FROM patient_info JOIN blood_tests ON pid JOIN prenatal_tests ON pid
    WHERE pregnant = 1 AND PREDICT(MODEL='los_model') > 7
    """
    plan = parse_query(sql, store)
    print("\n== unoptimized plan ==")
    print(plan.pretty())

    opt = CrossOptimizer(store, OptimizerConfig())
    oplan, report = opt.optimize(plan)
    print("\n== cross-optimizer report ==")
    print(report.pretty())
    print("\n== optimized plan ==")
    print(oplan.pretty())

    def timed(p, label):
        tabs = {n: store.get_table(n) for n in store.table_names()}
        fn = jax.jit(compile_plan(p, store))
        out = fn(tabs)                      # compile + warm
        jax.block_until_ready(out.valid)
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(tabs)
            jax.block_until_ready(out.valid)
        dt = (time.perf_counter() - t0) / 5
        print(f"{label}: {dt*1e3:.2f} ms/query")
        return out, dt

    r0, t_base = timed(plan, "unoptimized")
    r1, t_opt = timed(oplan, "optimized  ")
    d0, d1 = r0.to_pydict(), r1.to_pydict()
    assert d0["pid"] == d1["pid"]
    assert np.allclose(d0["los"], d1["los"], atol=1e-4)
    print(f"\nresults identical ({len(d1['pid'])} rows); "
          f"speedup {t_base/t_opt:.2f}x")
    print("\naudit log tail:")
    for rec in store.audit_log[-3:]:
        print(f"  {rec.action:10s} {rec.subject:14s} v{rec.version}")


if __name__ == "__main__":
    main()
