"""Multi-tenant SQL front door: sessions, parameterized queries, tenant
isolation and observability.

Registers two tenants on one :class:`PredictionService` — an interactive
tenant and a rate-limited batch tenant — then walks the front door:

1. ``Session.sql`` with named (``:lo``) and positional (``?``) params:
   100 distinct literal bindings reuse ONE compiled plan (zero warm
   compiles, shown live via a compile listener).
2. A positioned ``SqlError``: the caret snippet that a typo'd query
   produces, and ``SqlLookupError`` doubling as ``KeyError``.
3. Per-tenant backpressure: the batch tenant's own ``max_queue`` sheds
   its overflow while the interactive tenant keeps being served.
4. ``tenant_info()``: queue latency percentiles, coalesce rate,
   rejections and cache usage, per tenant.

Run:  PYTHONPATH=src python examples/sql_serving.py
"""

import numpy as np

from repro.core import ModelStore
from repro.core.codegen import add_compile_listener
from repro.core.sql_frontend import SqlError, SqlLookupError, parse_query
from repro.data import hospital_tables
from repro.ml import (DecisionTree, Pipeline, PipelineMetadata,
                      StandardScaler)
from repro.serve import (AdmissionConfig, AdmissionQueueFull,
                         PredictionService, TenantPolicy)


def build_store(n_rows: int = 5_000) -> ModelStore:
    store = ModelStore(principal="sql_serving_demo")
    tables = hospital_tables(n_rows)
    for name, t in tables.items():
        store.register_table(name, t)
    feats = ["age", "gender", "pregnant", "rcount"]
    pi = tables["patient_info"]
    data = {c: np.asarray(pi.column(c)) for c in pi.names}
    sc = StandardScaler(feats).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=6),
                    PipelineMetadata(name="los", task="regression"))
    pipe.fit({k: data[k] for k in feats}, data["length_of_stay"])
    store.register_model("los", pipe)
    return store


def main():
    store = build_store()
    service = PredictionService(
        store,
        admission=AdmissionConfig(latency_budget_s=2e-3,
                                  block_on_full=False),
        tenants={
            "interactive": TenantPolicy(weight=2.0),
            "batch": TenantPolicy(weight=0.5, max_queue=4,
                                  result_cache_entries=64),
        })

    # -- 1. sessions + parameterized queries -----------------------------
    print("== parameterized plan reuse ==")
    ui = service.session(tenant="interactive")
    print(f"opened {ui!r}")

    compiles = []
    unsubscribe = add_compile_listener(lambda plan: compiles.append(plan))
    sql = ("SELECT pid, age, PREDICT(MODEL='los') AS los "
           "FROM patient_info WHERE age > :lo AND age < :hi")
    out = ui.sql(sql, params={"lo": 30, "hi": 60})
    print(f"cold call: {len(compiles)} compile(s), "
          f"{int(np.asarray(out.valid).sum())} rows")
    cold = len(compiles)
    for lo in range(100):                       # 100 distinct bindings
        ui.sql(sql, params={"lo": lo % 40, "hi": 50 + lo % 30})
    print(f"100 distinct bindings later: "
          f"{len(compiles) - cold} warm compiles (one cached plan)")
    positional = ui.sql(
        "SELECT pid FROM patient_info WHERE age > ? ORDER BY age LIMIT 5",
        params=[60])
    print(f"positional params: {int(np.asarray(positional.valid).sum())} "
          f"rows (LIMIT 5)")
    unsubscribe()

    # -- 2. positioned SQL errors ----------------------------------------
    print("\n== positioned errors ==")
    try:
        parse_query("SELECT pid FRM patient_info WHERE age > 30", store)
    except SqlError as err:
        print(f"SqlError at offset {err.pos}:")
        print("\n".join("  " + line for line in str(err).splitlines()))
    try:
        parse_query("SELECT pid, nope FROM patient_info", store)
    except SqlLookupError as err:
        print(f"SqlLookupError (isinstance KeyError: "
              f"{isinstance(err, KeyError)}) at offset {err.pos}")

    # -- 3. per-tenant backpressure --------------------------------------
    print("\n== per-tenant backpressure ==")
    batch = service.session(tenant="batch")
    pi = store.get_table("patient_info")
    tickets, rejected = [], 0
    for i in range(64):
        try:
            tickets.append(batch.submit(
                sql, params={"lo": i % 50, "hi": 55 + i % 20},
                tables={"patient_info": pi.row_slice(0, 128)}))
        except AdmissionQueueFull:
            rejected += 1
    for t in tickets:
        t.result(timeout=60)
    print(f"batch tenant: {len(tickets)} served, {rejected} shed at its "
          f"own max_queue=4 — interactive stays unaffected:")
    print(f"  interactive probe: "
          f"{int(np.asarray(ui.sql(sql, params={'lo': 25, 'hi': 65}).valid).sum())} rows")

    # -- 4. per-tenant observability -------------------------------------
    print("\n== tenant_info ==")
    for name, row in sorted(service.tenant_info().items()):
        print(f"  {name}: served={row['served']} "
              f"rejections={row['rejections']} "
              f"p95={row['queue_p95_ms']:.1f}ms "
              f"coalesce_rate={row['coalesce_rate']:.2f} "
              f"cache_entries={row['result_cache_entries']}")
    stats = service.stats
    print(f"\nsql parses: {stats.sql_parses} "
          f"(cache hits: {stats.sql_parse_hits})")
    service.close()


if __name__ == "__main__":
    main()
