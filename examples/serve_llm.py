"""End-to-end serving driver (the paper's kind is inference/serving).

Boots a reduced-config LM from the assigned pool, serves a batch of
requests through the continuous-batching engine, and prints throughput +
latency stats.  Swap ``--arch`` for any of the ten assigned architectures.

Run:  PYTHONPATH=src python examples/serve_llm.py [--arch gemma2-2b]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs, reduced_config
from repro.models import build_model
from repro.serve import InferenceEngine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    print(f"serving reduced {args.arch}: {cfg.n_layers}L d{cfg.d_model} "
          f"({cfg.family})")
    model = build_model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(0))

    engine = InferenceEngine(model, ServeConfig(n_slots=args.slots,
                                                max_len=96, eos_token=-1))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 12, np.int64)
            .astype(np.int32),
            max_new_tokens=12, temperature=0.7 if i % 2 else 0.0))
    engine.run_until_drained(params)
    wall = time.time() - t0

    done = sorted(engine.completed, key=lambda r: r.rid)
    toks = sum(len(r.output) for r in done)
    print(f"\nserved {len(done)} requests / {toks} tokens in {wall:.1f}s "
          f"({toks/wall:.1f} tok/s on CPU)")
    for r in done[:4]:
        print(f"  req {r.rid}: tokens={r.output[:8]}... "
              f"ttft={1e3*(r.first_token_at-r.submitted_at):.0f}ms")


if __name__ == "__main__":
    main()
