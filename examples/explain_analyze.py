"""Observability tour: request traces, EXPLAIN ANALYZE, and the metrics
registry (ISSUE 9).

Runs a shared-prefix query pair (the result-cache splice demo) and a
partitioned scan through one :class:`PredictionService`, then shows:

1. ``service.explain(sql)`` — the optimized plan tree with partition
   pruning, strategy and splice annotations; ``analyze=True`` re-runs
   the exact compiled plan un-jitted with per-operator timing, so every
   row of the tree carries ``actual time=... rows=...``.
2. Request traces: the cold trace (optimize/codegen/execute spans), the
   warm trace (executable-cache hit), and the second query of the
   shared-prefix pair whose trace visibly contains the
   ``result_cache_splice`` span — the cross-query cache at work.
3. ``service.export_traces(path)`` — Chrome-trace JSON for
   chrome://tracing or https://ui.perfetto.dev.
4. ``service.metrics_text()`` — the Prometheus exposition unifying
   ServiceStats counters, cache gauges and latency histograms.

Run:  PYTHONPATH=src python examples/explain_analyze.py
"""

import numpy as np

from repro.core import ModelStore
from repro.data import hospital_tables
from repro.ml import (DecisionTree, Pipeline, PipelineMetadata,
                      StandardScaler)
from repro.serve import PredictionService

SQL_A = "SELECT pid, PREDICT(MODEL='los') AS score FROM patient_info"
# same inference prefix as SQL_A (no WHERE — a filter below the featurizer
# would change the subtree signature), one extra projected column: the
# shared prefix splices from the result cache
SQL_B = "SELECT pid, age, PREDICT(MODEL='los') AS score FROM patient_info"
# the EXPLAIN showcase query: the WHERE drives zone-map partition pruning
SQL_EXPLAIN = ("SELECT pid, age, PREDICT(MODEL='los') AS score "
               "FROM patient_info WHERE age > 40")


def build_store(n_rows: int = 20_000) -> ModelStore:
    store = ModelStore(principal="explain_demo")
    tables = hospital_tables(n_rows)
    pi = tables["patient_info"]
    # partitioned registration: zone maps feed the pruning annotations
    store.register_table("patient_info", pi, partition_rows=2_000)
    for name, t in tables.items():
        if name != "patient_info":
            store.register_table(name, t)
    feats = ["age", "gender", "pregnant", "rcount"]
    data = {c: np.asarray(pi.column(c)) for c in pi.names}
    sc = StandardScaler(feats).fit(data)
    pipe = Pipeline([sc], DecisionTree(task="regression", max_depth=6),
                    PipelineMetadata(name="los", task="regression"))
    pipe.fit({k: data[k] for k in feats}, data["length_of_stay"])
    store.register_model("los", pipe)
    return store


def main():
    store = build_store()
    service = PredictionService(store)

    # -- 1. EXPLAIN / EXPLAIN ANALYZE ------------------------------------
    print("=" * 72)
    print("EXPLAIN (no execution):\n")
    print(service.explain(SQL_EXPLAIN).pretty())

    print("\n" + "=" * 72)
    print("EXPLAIN ANALYZE (per-operator measured wall time):\n")
    print(service.explain(SQL_EXPLAIN, analyze=True).pretty())

    # -- 2. request traces: cold, warm, and the splice -------------------
    print("\n" + "=" * 72)
    print("Cold vs warm trace for the same query:\n")
    service.run(SQL_A)            # cold: optimize + codegen + execute
    service.run(SQL_A)            # warm: executable-cache hit
    cold, warm = service.traces()
    print(cold.pretty())
    print()
    print(warm.pretty())

    print("\n" + "=" * 72)
    print("Shared-prefix pair: the second query's trace shows the "
          "result-cache splice\n")
    out = service.run(SQL_B)      # splices SQL_A's materialized prefix
    spliced_trace = service.traces()[-1]
    print(spliced_trace.pretty())
    splice = spliced_trace.find("result_cache_splice")
    assert splice is not None and splice.attrs["hit"], \
        "expected the shared inference prefix to be served from cache"
    print(f"\nspliced rows: {int(np.asarray(out.valid).sum())} "
          f"(result_hits={service.stats.result_hits}, "
          f"spliced_executions={service.stats.spliced_executions})")

    # -- 3. Chrome-trace export ------------------------------------------
    path = "/tmp/repro_traces.json"
    doc = service.export_traces(path)
    print(f"\nwrote {len(doc['traceEvents'])} trace events to {path} "
          "(load in chrome://tracing or https://ui.perfetto.dev)")

    # -- 4. the metrics registry -----------------------------------------
    print("\n" + "=" * 72)
    print("Prometheus exposition (excerpt):\n")
    for line in service.metrics_text().splitlines():
        if any(k in line for k in ("exec_seconds", "cache_hits",
                                   "result_hits", "queue_depth")):
            print(line)

    service.close()


if __name__ == "__main__":
    main()
