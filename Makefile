.PHONY: verify verify-tier1 bench-subplan bench-batching bench-sharded \
	bench-join-agg bench-tenants bench-json bench-rebaseline \
	bench-trajectory-series

# Tier-1 gate: full suite, fail fast (ROADMAP "Tier-1 verify").  verify.sh
# exports REPRO_TEST_TIMEOUT so the threaded admission-loop tests fail
# fast (all-thread tracebacks) instead of hanging the gate.
verify:
	sh scripts/verify.sh

# Just the serving-layer battery (signatures, result cache, eviction,
# continuous batching).
verify-tier1:
	sh scripts/verify.sh -m tier1

bench-subplan:
	PYTHONPATH=src python -m benchmarks.subplan_reuse

bench-batching:
	PYTHONPATH=src python -m benchmarks.continuous_batching

# Partitioned-table sharded scan on 8 simulated host devices (the module
# sets xla_force_host_platform_device_count before importing jax).
bench-sharded:
	PYTHONPATH=src python -m benchmarks.sharded_scan

# Partition-wise sharded FK join + two-phase aggregation over predictions
# on 8 simulated host devices (same self-re-exec pattern).
bench-join-agg:
	PYTHONPATH=src python -m benchmarks.sharded_join_agg

# Multi-tenant front door under an adversarial flooder: DRR drain +
# per-tenant backpressure keep the compliant cohort's p95 within 2.5x
# its flood-free value.
bench-tenants:
	PYTHONPATH=src python -m benchmarks.multi_tenant_saturation

# The quick benchmark suite with the machine-readable export + trajectory
# check — exactly what the bench-trajectory CI job runs.  BENCH_N is
# numbered per PR so the uploaded artifacts form a perf history.
bench-json:
	PYTHONPATH=src python -m benchmarks.run --quick --json BENCH_9.json
	PYTHONPATH=src python -m benchmarks.check_trajectory BENCH_9.json \
		benchmarks/baseline.json

# Rewrite benchmarks/baseline.json from the latest export after an
# *intentional* perf-profile change (then commit the diff).
bench-rebaseline:
	PYTHONPATH=src python -m benchmarks.check_trajectory BENCH_9.json \
		benchmarks/baseline.json --rebaseline

# Fold every committed BENCH_N.json into one perf-history series file
# (plus a tracked-metric sparkline table on stdout).
bench-trajectory-series:
	python scripts/plot_trajectory.py BENCH_*.json \
		--out trajectory_series.json --baseline benchmarks/baseline.json
