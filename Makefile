.PHONY: verify verify-tier1 bench-subplan

# Tier-1 gate: full suite, fail fast (ROADMAP "Tier-1 verify").
verify:
	sh scripts/verify.sh

# Just the serving-layer battery (signatures, result cache, eviction).
verify-tier1:
	sh scripts/verify.sh -m tier1

bench-subplan:
	PYTHONPATH=src python -m benchmarks.subplan_reuse
