#!/usr/bin/env sh
# Tier-1 verification: the whole test suite, fail-fast, exactly as the
# ROADMAP specifies.  Extra pytest args pass through (e.g.
# `scripts/verify.sh -m tier1` for just the serving battery).
set -e
cd "$(dirname "$0")/.."
# Watchdog cap for tests marked timeout_guard (the threaded admission-loop
# battery): a wedged background loop dumps all-thread tracebacks and fails
# the run instead of hanging tier-1.  See tests/conftest.py.
REPRO_TEST_TIMEOUT="${REPRO_TEST_TIMEOUT:-300}"
export REPRO_TEST_TIMEOUT
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    -W error::pytest.PytestUnknownMarkWarning "$@"
