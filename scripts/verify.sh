#!/usr/bin/env sh
# Tier-1 verification: the whole test suite, fail-fast, exactly as the
# ROADMAP specifies.  Extra pytest args pass through (e.g.
# `scripts/verify.sh -m tier1` for just the serving battery).
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    -W error::pytest.PytestUnknownMarkWarning "$@"
