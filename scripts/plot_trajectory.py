"""Fold per-PR benchmark exports into one perf-trajectory series file.

    python scripts/plot_trajectory.py BENCH_*.json \
        [--out trajectory_series.json] [--baseline benchmarks/baseline.json]

Each ``BENCH_N.json`` (written by ``benchmarks.run --json``, numbered
per PR by the ``bench-trajectory`` CI job) is one point in time; this
script folds any number of them into a single series document::

    {
      "schema": 1,
      "runs": [6, 8, 9],
      "series": {
        "shuffle_join/mesh8": {
          "us_per_call": {"6": 81234.5, "8": 79812.1, ...},
          "speedup":     {"6": 2.61,    "8": 2.70,    ...}
        },
        ...
      }
    }

so dashboards (or a later matplotlib pass) can plot every benchmark's
history without re-downloading N artifacts.  Rows/metrics missing from
an export simply have no point for that run — benchmarks added later
start where they started.  With ``--baseline`` the stdout table is
restricted to the tracked metrics (the ones the trajectory gate
defends); the series file always contains everything.

Dependency-free on purpose: CI runs it right after the bench job and
uploads the series next to the raw export.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

_RUN_RE = re.compile(r"BENCH[_-](\d+)\.json$")


def run_number(path: str) -> int:
    """PR number from a ``BENCH_N.json`` filename (the per-PR artifact
    naming convention); falls back to file order via -1."""
    m = _RUN_RE.search(os.path.basename(path))
    return int(m.group(1)) if m else -1


def fold(paths: list) -> dict:
    points = []
    for path in paths:
        with open(path) as fh:
            export = json.load(fh)
        points.append((run_number(path), path, export))
    points.sort(key=lambda p: (p[0], p[1]))

    runs = [run for run, _, _ in points]
    series: dict = {}
    for run, _, export in points:
        for name, row in export.get("benchmarks", {}).items():
            entry = series.setdefault(name, {})
            entry.setdefault("us_per_call", {})[str(run)] = \
                row.get("us_per_call")
            for metric, value in row.get("derived", {}).items():
                if isinstance(value, (int, float)):
                    entry.setdefault(metric, {})[str(run)] = value
    return {"schema": 1, "runs": runs, "series": series}


def spark(values: list) -> str:
    """Unicode sparkline over the non-None values (min..max scaled)."""
    blocks = "▁▂▃▄▅▆▇█"
    nums = [v for v in values if isinstance(v, (int, float))]
    if not nums:
        return ""
    lo, hi = min(nums), max(nums)
    out = []
    for v in values:
        if not isinstance(v, (int, float)):
            out.append(" ")
        elif hi == lo:
            out.append(blocks[3])
        else:
            out.append(blocks[round((v - lo) / (hi - lo)
                                    * (len(blocks) - 1))])
    return "".join(out)


def render(doc: dict, baseline: dict) -> str:
    runs = [str(r) for r in doc["runs"]]
    lines = [f"runs: {' '.join(runs)}"]
    for name in sorted(doc["series"]):
        tracked = baseline.get(name)
        metrics = doc["series"][name]
        for metric in sorted(metrics):
            if baseline and (tracked is None
                             or metric not in tracked):
                continue
            vals = [metrics[metric].get(r) for r in runs]
            shown = [f"{v:g}" if isinstance(v, (int, float)) else "-"
                     for v in vals]
            lines.append(f"{name}.{metric:<16} {spark(vals)}  "
                         f"{' -> '.join(shown)}")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("exports", nargs="+",
                    help="BENCH_N.json files from benchmarks.run --json")
    ap.add_argument("--out", default="trajectory_series.json",
                    help="series file to write (default "
                         "trajectory_series.json)")
    ap.add_argument("--baseline", default=None,
                    help="baseline.json: restrict the printed table to "
                         "tracked metrics")
    args = ap.parse_args()

    doc = fold(args.exports)
    with open(args.out, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {len(doc['series'])} benchmark series over "
          f"{len(doc['runs'])} run(s) to {args.out}", file=sys.stderr)

    baseline = {}
    if args.baseline:
        with open(args.baseline) as fh:
            baseline = json.load(fh)
    print(render(doc, baseline))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
