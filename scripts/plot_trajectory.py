"""Fold per-PR benchmark exports into one perf-trajectory series file.

    python scripts/plot_trajectory.py BENCH_*.json \
        [--out trajectory_series.json] [--baseline benchmarks/baseline.json]

Each ``BENCH_N.json`` (written by ``benchmarks.run --json``, numbered
per PR by the ``bench-trajectory`` CI job) is one point in time; this
script folds any number of them into a single series document::

    {
      "schema": 1,
      "runs": [6, 8, 9],
      "series": {
        "shuffle_join/mesh8": {
          "us_per_call": {"6": 81234.5, "8": 79812.1, ...},
          "speedup":     {"6": 2.61,    "8": 2.70,    ...}
        },
        ...
      }
    }

so dashboards (or a later matplotlib pass) can plot every benchmark's
history without re-downloading N artifacts.  Rows/metrics missing from
an export simply have no point for that run — benchmarks added later
start where they started.  With ``--baseline`` the stdout table is
restricted to the tracked metrics (the ones the trajectory gate
defends); the series file always contains everything.

With ``--plots OUTDIR`` the script additionally renders the series as
browsable history: one PNG per tracked benchmark row (every numeric
metric of that row on one axes, run number on x) plus an ``index.html``
linking them — the document the ``publish-trajectory`` CI job pushes to
``gh-pages``.  Plot rendering is the one mode that needs matplotlib;
the fold/series path stays dependency-free on purpose: CI runs it right
after the bench job and uploads the series next to the raw export.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

_RUN_RE = re.compile(r"BENCH[_-](\d+)\.json$")


def run_number(path: str) -> int:
    """PR number from a ``BENCH_N.json`` filename (the per-PR artifact
    naming convention); falls back to file order via -1."""
    m = _RUN_RE.search(os.path.basename(path))
    return int(m.group(1)) if m else -1


def fold(paths: list) -> dict:
    points = []
    for path in paths:
        with open(path) as fh:
            export = json.load(fh)
        points.append((run_number(path), path, export))
    points.sort(key=lambda p: (p[0], p[1]))

    runs = [run for run, _, _ in points]
    series: dict = {}
    for run, _, export in points:
        for name, row in export.get("benchmarks", {}).items():
            entry = series.setdefault(name, {})
            entry.setdefault("us_per_call", {})[str(run)] = \
                row.get("us_per_call")
            for metric, value in row.get("derived", {}).items():
                if isinstance(value, (int, float)):
                    entry.setdefault(metric, {})[str(run)] = value
    return {"schema": 1, "runs": runs, "series": series}


def spark(values: list) -> str:
    """Unicode sparkline over the non-None values (min..max scaled)."""
    blocks = "▁▂▃▄▅▆▇█"
    nums = [v for v in values if isinstance(v, (int, float))]
    if not nums:
        return ""
    lo, hi = min(nums), max(nums)
    out = []
    for v in values:
        if not isinstance(v, (int, float)):
            out.append(" ")
        elif hi == lo:
            out.append(blocks[3])
        else:
            out.append(blocks[round((v - lo) / (hi - lo)
                                    * (len(blocks) - 1))])
    return "".join(out)


def render(doc: dict, baseline: dict) -> str:
    runs = [str(r) for r in doc["runs"]]
    lines = [f"runs: {' '.join(runs)}"]
    for name in sorted(doc["series"]):
        tracked = baseline.get(name)
        metrics = doc["series"][name]
        for metric in sorted(metrics):
            if baseline and (tracked is None
                             or metric not in tracked):
                continue
            vals = [metrics[metric].get(r) for r in runs]
            shown = [f"{v:g}" if isinstance(v, (int, float)) else "-"
                     for v in vals]
            lines.append(f"{name}.{metric:<16} {spark(vals)}  "
                         f"{' -> '.join(shown)}")
    return "\n".join(lines)


def _slug(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", name)


def render_plots(doc: dict, baseline: dict, outdir: str) -> list:
    """One PNG per benchmark row (tracked rows only when a baseline is
    given, every row otherwise) and an ``index.html`` linking them.
    Requires matplotlib — the only mode of this script that does."""
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError as exc:            # pragma: no cover - CI installs it
        raise SystemExit(f"--plots needs matplotlib ({exc}); "
                         f"pip install matplotlib or drop --plots")
    os.makedirs(outdir, exist_ok=True)
    runs = doc["runs"]
    names = [n for n in sorted(doc["series"])
             if not baseline or n in baseline]
    pngs = []
    for name in names:
        metrics = doc["series"][name]
        fig, ax = plt.subplots(figsize=(6.4, 3.2))
        for metric in sorted(metrics):
            if metric == "us_per_call" and len(metrics) > 1:
                continue                  # derived metrics tell the story
            pts = [(r, metrics[metric].get(str(r))) for r in runs]
            pts = [(r, v) for r, v in pts if isinstance(v, (int, float))]
            if not pts:
                continue
            ax.plot([r for r, _ in pts], [v for _, v in pts],
                    marker="o", label=metric)
        if not ax.lines:
            plt.close(fig)
            continue
        floors = baseline.get(name, {}) if baseline else {}
        for metric, floor_of in floors.items():
            if isinstance(floor_of, dict) and "min_ratio" in floor_of:
                ax.axhline(floor_of["min_ratio"], ls="--", lw=0.8,
                           color="grey")
        ax.set_title(name)
        ax.set_xlabel("PR / BENCH_N")
        ax.set_xticks(runs)
        ax.grid(True, alpha=0.3)
        ax.legend(fontsize=8)
        fig.tight_layout()
        png = f"{_slug(name)}.png"
        fig.savefig(os.path.join(outdir, png), dpi=120)
        plt.close(fig)
        pngs.append((name, png))

    items = "\n".join(
        f'    <h2>{name}</h2>\n    <img src="{png}" alt="{name}">'
        for name, png in pngs)
    html = ("<!DOCTYPE html>\n<html>\n<head>\n"
            "  <meta charset=\"utf-8\">\n"
            "  <title>Benchmark trajectory</title>\n"
            "  <style>body{font-family:sans-serif;max-width:720px;"
            "margin:2em auto}img{max-width:100%}</style>\n"
            "</head>\n<body>\n"
            f"  <h1>Benchmark trajectory</h1>\n"
            f"  <p>Runs (PR numbers): {', '.join(map(str, runs))}. "
            "Dashed lines are committed baseline floors.</p>\n"
            f"{items}\n</body>\n</html>\n")
    with open(os.path.join(outdir, "index.html"), "w") as fh:
        fh.write(html)
    print(f"rendered {len(pngs)} plot(s) + index.html to {outdir}",
          file=sys.stderr)
    return pngs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("exports", nargs="+",
                    help="BENCH_N.json files from benchmarks.run --json")
    ap.add_argument("--out", default="trajectory_series.json",
                    help="series file to write (default "
                         "trajectory_series.json)")
    ap.add_argument("--baseline", default=None,
                    help="baseline.json: restrict the printed table to "
                         "tracked metrics")
    ap.add_argument("--plots", metavar="OUTDIR", default=None,
                    help="render per-benchmark PNG history plots plus an "
                         "index.html into OUTDIR (needs matplotlib)")
    args = ap.parse_args()

    doc = fold(args.exports)
    with open(args.out, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {len(doc['series'])} benchmark series over "
          f"{len(doc['runs'])} run(s) to {args.out}", file=sys.stderr)

    baseline = {}
    if args.baseline:
        with open(args.baseline) as fh:
            baseline = json.load(fh)
    print(render(doc, baseline))
    if args.plots:
        render_plots(doc, baseline, args.plots)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
